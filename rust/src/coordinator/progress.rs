//! Progress broadcast substrate (no tokio): a multi-subscriber channel
//! over `std::sync::mpsc`, plus the shared job status cell.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs;

use super::job::{JobPhase, ParamUpdate, Snapshot};

/// `snapshot.publish_skipped` — sends that early-returned because nobody
/// was subscribed. The sole production `Broadcast` carries snapshots,
/// hence the metric's name.
fn publish_skipped() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("snapshot.publish_skipped"))
}

/// `snapshot.subscribers_dropped` — dead receivers pruned during a send.
fn subscribers_dropped() -> &'static Arc<obs::Counter> {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("snapshot.subscribers_dropped"))
}

/// `snapshot.fanout_ns` — how long one publish spends cloning into
/// subscriber channels.
fn fanout_ns() -> &'static Arc<obs::Histogram> {
    static H: OnceLock<Arc<obs::Histogram>> = OnceLock::new();
    H.get_or_init(|| obs::registry().histogram("snapshot.fanout_ns"))
}

/// Clone-fanout broadcast channel: every subscriber gets every message
/// sent after it subscribed. Dead subscribers are pruned on send.
pub struct Broadcast<T: Clone> {
    subs: Mutex<Vec<Sender<T>>>,
}

impl<T: Clone> Default for Broadcast<T> {
    fn default() -> Self {
        Self { subs: Mutex::new(Vec::new()) }
    }
}

impl<T: Clone> Broadcast<T> {
    pub fn subscribe(&self) -> Receiver<T> {
        let (tx, rx) = channel();
        self.subs.lock().unwrap().push(tx);
        rx
    }

    pub fn send(&self, msg: T) {
        let mut subs = self.subs.lock().unwrap();
        if subs.is_empty() {
            // Don't clone the message (snapshot position buffers are
            // Arc-shared but the wrapper still costs) for nobody.
            publish_skipped().inc();
            return;
        }
        let before = subs.len();
        let t0 = obs::now_ns();
        subs.retain(|s| s.send(msg.clone()).is_ok());
        fanout_ns().record(obs::now_ns().saturating_sub(t0));
        subscribers_dropped().add((before - subs.len()) as u64);
    }

    pub fn subscriber_count(&self) -> usize {
        self.subs.lock().unwrap().len()
    }
}

/// Shared mutable view of a running job: phase, snapshots, and the
/// control surface the scheduler polls between step quanta (stop, pause,
/// pending hyperparameter update).
#[derive(Clone)]
pub struct JobState {
    phase: Arc<Mutex<JobPhase>>,
    latest: Arc<Mutex<Option<Snapshot>>>,
    stop: Arc<AtomicBool>,
    paused: Arc<AtomicBool>,
    pending_update: Arc<Mutex<Option<ParamUpdate>>>,
    pub snapshots: Arc<Broadcast<Snapshot>>,
}

impl Default for JobState {
    fn default() -> Self {
        Self {
            phase: Arc::new(Mutex::new(JobPhase::Queued)),
            latest: Arc::new(Mutex::new(None)),
            stop: Arc::new(AtomicBool::new(false)),
            paused: Arc::new(AtomicBool::new(false)),
            pending_update: Arc::new(Mutex::new(None)),
            snapshots: Arc::new(Broadcast::default()),
        }
    }
}

impl JobState {
    pub fn phase(&self) -> JobPhase {
        self.phase.lock().unwrap().clone()
    }

    pub fn set_phase(&self, p: JobPhase) {
        *self.phase.lock().unwrap() = p;
    }

    pub fn latest_snapshot(&self) -> Option<Snapshot> {
        self.latest.lock().unwrap().clone()
    }

    pub fn publish(&self, s: Snapshot) {
        *self.latest.lock().unwrap() = Some(s.clone());
        self.snapshots.send(s);
    }

    /// User-driven early termination (the A-tSNE interaction).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Ask the scheduler to park this job at the next step boundary.
    pub fn request_pause(&self) {
        self.paused.store(true, Ordering::SeqCst);
    }

    /// Clear the pause flag (the service also re-enqueues the job).
    pub fn clear_pause(&self) {
        self.paused.store(false, Ordering::SeqCst);
    }

    pub fn pause_requested(&self) -> bool {
        self.paused.load(Ordering::SeqCst)
    }

    /// Queue a hyperparameter update for the scheduler to apply at the
    /// next step boundary; updates arriving before the previous one was
    /// consumed merge (later fields win).
    pub fn push_update(&self, update: ParamUpdate) {
        let mut slot = self.pending_update.lock().unwrap();
        *slot = Some(match slot.take() {
            Some(prev) => prev.merged_with(&update),
            None => update,
        });
    }

    /// Claim the pending update, if any.
    pub fn take_update(&self) -> Option<ParamUpdate> {
        self.pending_update.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_reaches_all_subscribers() {
        let b: Broadcast<u32> = Broadcast::default();
        let r1 = b.subscribe();
        let r2 = b.subscribe();
        b.send(7);
        assert_eq!(r1.recv().unwrap(), 7);
        assert_eq!(r2.recv().unwrap(), 7);
    }

    #[test]
    fn dead_subscribers_are_pruned() {
        let b: Broadcast<u32> = Broadcast::default();
        {
            let _r = b.subscribe();
        } // dropped
        let r2 = b.subscribe();
        b.send(1);
        assert_eq!(b.subscriber_count(), 1);
        assert_eq!(r2.recv().unwrap(), 1);
    }

    #[test]
    fn pause_and_update_controls_roundtrip() {
        let js = JobState::default();
        assert!(!js.pause_requested());
        js.request_pause();
        assert!(js.pause_requested());
        js.clear_pause();
        assert!(!js.pause_requested());

        assert!(js.take_update().is_none());
        js.push_update(ParamUpdate { eta: Some(10.0), iters: Some(5), ..Default::default() });
        js.push_update(ParamUpdate { eta: Some(20.0), ..Default::default() });
        let u = js.take_update().expect("merged update pending");
        assert_eq!(u.eta, Some(20.0), "later update wins");
        assert_eq!(u.iters, Some(5), "earlier field survives the merge");
        assert!(js.take_update().is_none(), "take consumes");
    }

    #[test]
    fn job_state_roundtrip() {
        let js = JobState::default();
        assert_eq!(js.phase(), JobPhase::Queued);
        js.set_phase(JobPhase::Knn);
        assert_eq!(js.phase(), JobPhase::Knn);
        assert!(!js.stop_requested());
        js.request_stop();
        assert!(js.stop_requested());
        assert!(js.latest_snapshot().is_none());
        js.publish(Snapshot {
            iter: 3,
            kl_est: 1.0,
            elapsed_s: 0.1,
            positions: Arc::new(vec![0.0, 0.0]),
            published_ns: obs::now_ns(),
        });
        assert_eq!(js.latest_snapshot().unwrap().iter, 3);
    }
}
