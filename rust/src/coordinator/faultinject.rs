//! Fault-injection substrate: named fault points compiled into the
//! serving stack, free when disarmed.
//!
//! A *fault point* is a named site where a provoked failure can be
//! injected — a store write that errors, an engine step that panics, a
//! connection handler that stalls. Call sites ask [`fire`] whether the
//! fault should trigger *now*; with nothing armed that is one relaxed
//! atomic load and a predicted branch (the same discipline as
//! [`crate::obs::set_enabled`], pinned <1 ns by the `faultinject`
//! section of `benches/micro_hotpath.rs`), so the points stay compiled
//! into release builds and chaos tests exercise the exact binary that
//! serves traffic.
//!
//! Points are armed with a [`Trigger`] — one-shot, every-Nth check, or
//! per-check probability from a private seeded xorshift (deterministic
//! chaos runs) — either programmatically ([`arm`], [`arm_spec`]), from
//! the CLI (`serve --fault <spec>`), or over the wire (the `fault`
//! protocol command), so chaos harnesses drive the real TCP surface.
//!
//! Spec grammar (comma-separated):
//!
//! ```text
//! point=once            fire on the first check, then never again
//! point=every:N         fire on every Nth check (N >= 1)
//! point=prob:P[@SEED]   fire each check with probability P in [0,1]
//! ```
//!
//! The registry is process-global (chaos tests own their process);
//! scoped test use goes through [`guard`], which disarms everything on
//! drop.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

/// Store write fails with a transient I/O error (exercises retry).
pub const STORE_WRITE: &str = "store.write";
/// Simulated kill between the tmp-file write and the atomic rename:
/// the tmp file is left behind and the destination never appears.
pub const STORE_WRITE_CRASH: &str = "store.write_crash";
/// Store read sees a corrupted record (checksum flips on the way in).
pub const STORE_READ_CORRUPT: &str = "store.read_corrupt";
/// Journal append fails with a transient I/O error.
pub const JOURNAL_APPEND: &str = "journal.append";
/// The engine step panics mid-quantum (exercises worker catch_unwind).
pub const ENGINE_STEP_PANIC: &str = "engine.step_panic";
/// The connection handler stalls before responding.
pub const NET_STALL: &str = "net.stall";
/// A snapshot subscriber consumes slowly (exercises drop-oldest/evict).
pub const SNAPSHOT_SLOW_SUBSCRIBER: &str = "snapshot.slow_subscriber";
/// The router's heartbeat probe to one worker is dropped (the worker
/// looks silent without actually dying — exercises failure detection).
pub const CLUSTER_HEARTBEAT_DROP: &str = "cluster.heartbeat.drop";
/// The router's per-heartbeat checkpoint replication pull is skipped
/// (a failover then resumes from an older replica, or from scratch).
pub const CLUSTER_REPLICATE_FAIL: &str = "cluster.replicate.fail";
/// Reserved for faultinject's own unit tests; wired nowhere.
pub const TEST_POINT: &str = "test.point";

/// Every known fault point. Arming an unknown name is an error, so a
/// typoed chaos spec fails loudly instead of silently testing nothing.
pub const POINTS: &[&str] = &[
    STORE_WRITE,
    STORE_WRITE_CRASH,
    STORE_READ_CORRUPT,
    JOURNAL_APPEND,
    ENGINE_STEP_PANIC,
    NET_STALL,
    SNAPSHOT_SLOW_SUBSCRIBER,
    CLUSTER_HEARTBEAT_DROP,
    CLUSTER_REPLICATE_FAIL,
    TEST_POINT,
];

/// Master switch: false ⇒ every [`fire`] is one relaxed load + branch.
/// Flipped true by [`arm`]/[`arm_spec`], false when the last point is
/// disarmed — callers never manage it directly.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// True if any fault point is armed (the fast-path gate [`fire`] reads).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// When an armed fault point fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// Fire on the first check, then never again.
    Once,
    /// Fire on every `n`th check (`n >= 1`; `every:1` fires always).
    EveryNth(u64),
    /// Fire each check with probability `p` from a private seeded rng.
    Prob(f64),
}

impl std::fmt::Display for Trigger {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Trigger::Once => write!(f, "once"),
            Trigger::EveryNth(n) => write!(f, "every:{n}"),
            Trigger::Prob(p) => write!(f, "prob:{p}"),
        }
    }
}

struct Armed {
    trigger: Trigger,
    rng: u64,
    checks: u64,
    fired: u64,
}

/// One armed point's counters, as reported by the `fault` command.
pub struct PointStatus {
    pub point: &'static str,
    pub trigger: String,
    pub checks: u64,
    pub fired: u64,
}

fn registry() -> &'static Mutex<HashMap<&'static str, Armed>> {
    static REG: OnceLock<Mutex<HashMap<&'static str, Armed>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

fn canonical(point: &str) -> Result<&'static str, String> {
    POINTS
        .iter()
        .find(|&&p| p == point)
        .copied()
        .ok_or_else(|| format!("unknown fault point '{}' (known: {})", point, POINTS.join(", ")))
}

/// Should the fault at `point` trigger now? The serving hot paths call
/// this unconditionally; with nothing armed it is one relaxed load.
#[inline]
pub fn fire(point: &'static str) -> bool {
    if !ENABLED.load(Ordering::Relaxed) {
        return false;
    }
    fire_slow(point)
}

#[cold]
#[inline(never)]
fn fire_slow(point: &str) -> bool {
    let mut reg = registry().lock().unwrap();
    let Some(armed) = reg.get_mut(point) else {
        return false;
    };
    armed.checks += 1;
    let hit = match armed.trigger {
        Trigger::Once => armed.fired == 0,
        Trigger::EveryNth(n) => armed.checks % n.max(1) == 0,
        Trigger::Prob(p) => {
            armed.rng = xorshift(armed.rng);
            ((armed.rng >> 11) as f64 / (1u64 << 53) as f64) < p
        }
    };
    if hit {
        armed.fired += 1;
    }
    hit
}

fn xorshift(mut s: u64) -> u64 {
    s ^= s << 13;
    s ^= s >> 7;
    s ^= s << 17;
    s
}

/// Arm `point` with `trigger`. `seed` feeds the [`Trigger::Prob`] rng
/// (0 ⇒ a fixed default, still deterministic). Re-arming replaces the
/// trigger and resets the counters. Flips the global switch on.
pub fn arm(point: &str, trigger: Trigger, seed: u64) -> Result<(), String> {
    let canon = canonical(point)?;
    if let Trigger::Prob(p) = trigger {
        if !(0.0..=1.0).contains(&p) || !p.is_finite() {
            return Err(format!("probability {p} outside [0, 1]"));
        }
    }
    if let Trigger::EveryNth(0) = trigger {
        return Err("every:N needs N >= 1".to_string());
    }
    let rng = if seed == 0 { 0x9e37_79b9_7f4a_7c15 } else { seed };
    let armed = Armed { trigger, rng, checks: 0, fired: 0 };
    registry().lock().unwrap().insert(canon, armed);
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Arm a comma-separated spec, e.g.
/// `store.write=every:3,engine.step_panic=prob:0.05@42`. Atomic per
/// part: earlier parts of a spec that fails mid-way stay armed.
pub fn arm_spec(spec: &str) -> Result<(), String> {
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (point, trig) = part
            .split_once('=')
            .ok_or_else(|| format!("bad fault spec '{part}': expected point=trigger"))?;
        let (trigger, seed) = parse_trigger(trig.trim())?;
        arm(point.trim(), trigger, seed)?;
    }
    Ok(())
}

fn parse_trigger(s: &str) -> Result<(Trigger, u64), String> {
    if s == "once" {
        return Ok((Trigger::Once, 0));
    }
    if let Some(n) = s.strip_prefix("every:") {
        let n: u64 = n.parse().map_err(|_| format!("bad every-nth count '{n}'"))?;
        if n == 0 {
            return Err("every:N needs N >= 1".to_string());
        }
        return Ok((Trigger::EveryNth(n), 0));
    }
    if let Some(rest) = s.strip_prefix("prob:") {
        let (p_str, seed) = match rest.split_once('@') {
            Some((p, s)) => (p, s.parse::<u64>().map_err(|_| format!("bad seed '{s}'"))?),
            None => (rest, 0),
        };
        let p: f64 = p_str.parse().map_err(|_| format!("bad probability '{p_str}'"))?;
        return Ok((Trigger::Prob(p), seed));
    }
    Err(format!("bad trigger '{s}': expected once | every:N | prob:P[@SEED]"))
}

/// Disarm one point. Returns whether it was armed; flips the global
/// switch off when the registry empties.
pub fn disarm(point: &str) -> bool {
    let mut reg = registry().lock().unwrap();
    let was = reg.remove(point).is_some();
    if reg.is_empty() {
        ENABLED.store(false, Ordering::Relaxed);
    }
    was
}

/// Disarm everything and switch the fast-path gate off.
pub fn disarm_all() {
    let mut reg = registry().lock().unwrap();
    reg.clear();
    ENABLED.store(false, Ordering::Relaxed);
}

/// Counters for every armed point, sorted by name.
pub fn status() -> Vec<PointStatus> {
    let reg = registry().lock().unwrap();
    let mut out: Vec<PointStatus> = reg
        .iter()
        .map(|(point, a)| PointStatus {
            point,
            trigger: a.trigger.to_string(),
            checks: a.checks,
            fired: a.fired,
        })
        .collect();
    out.sort_by(|a, b| a.point.cmp(b.point));
    out
}

/// Arms `spec` and returns a guard that disarms *everything* on drop —
/// scoped fault windows for tests.
pub fn guard(spec: &str) -> Result<FaultGuard, String> {
    arm_spec(spec)?;
    Ok(FaultGuard)
}

/// Disarms all fault points when dropped. See [`guard`].
pub struct FaultGuard;

impl Drop for FaultGuard {
    fn drop(&mut self) {
        disarm_all();
    }
}

/// Serialises unit tests that touch the process-global registry — this
/// module's own plus the protocol layer's `fault`-command tests, which
/// share one process under `cargo test`. Integration-test binaries run
/// in their own processes and don't need it.
#[cfg(test)]
pub(crate) fn test_registry_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and `cargo test` threads run in
    // parallel, so every test here serialises on one lock and touches
    // only TEST_POINT (wired nowhere in the serving stack).
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        test_registry_lock()
    }

    #[test]
    fn disarmed_point_never_fires() {
        let _l = lock();
        disarm_all();
        assert!(!enabled());
        assert!(!fire(TEST_POINT));
    }

    #[test]
    fn once_fires_exactly_once() {
        let _l = lock();
        let _g = guard("test.point=once").unwrap();
        assert!(enabled());
        assert!(fire(TEST_POINT));
        assert!(!fire(TEST_POINT));
        assert!(!fire(TEST_POINT));
        let st = status();
        assert_eq!(st.len(), 1);
        assert_eq!((st[0].checks, st[0].fired), (3, 1));
    }

    #[test]
    fn every_nth_fires_on_the_nth_check() {
        let _l = lock();
        let _g = guard("test.point=every:3").unwrap();
        let fires: Vec<bool> = (0..9).map(|_| fire(TEST_POINT)).collect();
        assert_eq!(
            fires,
            vec![false, false, true, false, false, true, false, false, true]
        );
    }

    #[test]
    fn prob_is_deterministic_in_its_seed_and_roughly_calibrated() {
        let _l = lock();
        let run = |seed: u64| -> Vec<bool> {
            let _g = guard(&format!("test.point=prob:0.25@{seed}")).unwrap();
            (0..4000).map(|_| fire(TEST_POINT)).collect()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "same seed must replay identically");
        let hits = a.iter().filter(|&&h| h).count();
        assert!(
            (600..=1400).contains(&hits),
            "p=0.25 over 4000 checks fired {hits} times"
        );
    }

    #[test]
    fn rearming_resets_counters_and_guard_disarms() {
        let _l = lock();
        {
            let _g = guard("test.point=every:1").unwrap();
            assert!(fire(TEST_POINT));
            arm(TEST_POINT, Trigger::Once, 0).unwrap();
            assert_eq!(status()[0].checks, 0);
            assert!(fire(TEST_POINT));
        }
        assert!(!enabled());
        assert!(status().is_empty());
    }

    #[test]
    fn bad_specs_are_loud_errors() {
        let _l = lock();
        disarm_all();
        assert!(arm_spec("nosuch.point=once").is_err());
        assert!(arm_spec("test.point").is_err());
        assert!(arm_spec("test.point=every:0").is_err());
        assert!(arm_spec("test.point=prob:1.5").is_err());
        assert!(arm_spec("test.point=prob:x").is_err());
        assert!(arm_spec("test.point=sometimes").is_err());
        assert!(!enabled(), "failed arms must not flip the switch");
    }

    #[test]
    fn prop_valid_trigger_specs_round_trip_through_display() {
        use crate::util::prop::{check, Gen};
        let valid = Gen::new(|r: &mut crate::util::rng::Rng| -> (String, u64) {
            match r.below(4) {
                0 => ("once".to_string(), 0),
                1 => (format!("every:{}", r.below(1_000_000) + 1), 0),
                2 => (format!("prob:{}", r.f64()), 0),
                _ => {
                    let seed = r.next_u64() | 1; // nonzero, so the echo is visible
                    (format!("prob:{}@{seed}", r.f64()), seed)
                }
            }
        });
        check("faultinject.trigger_round_trip", &valid, |(spec, want_seed)| {
            let (trig, seed) =
                parse_trigger(spec).map_err(|e| format!("valid spec rejected: {e}"))?;
            if seed != *want_seed {
                return Err(format!("seed {seed} != expected {want_seed}"));
            }
            // Display drops the seed (it is rng state, not grammar), but
            // must reproduce the trigger shape exactly — including f64
            // probabilities, whose Display is shortest-round-trip.
            let shown = trig.to_string();
            let (trig2, seed2) =
                parse_trigger(&shown).map_err(|e| format!("display form '{shown}' rejected: {e}"))?;
            if trig2 != trig || seed2 != 0 {
                return Err(format!("{spec} -> {trig} -> {trig2} (seed {seed2})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_invalid_specs_are_structured_errors_and_arm_nothing() {
        use crate::util::prop::{check, Gen};
        let _l = lock();
        disarm_all();
        const BADS: &[&str] = &[
            "",
            ":",
            "sometimes",
            "Once",
            "once:1",
            "every:",
            "every:0",
            "every:-3",
            "every:abc",
            "every:1 extra",
            "prob:",
            "prob:abc",
            "prob:1.0.1",
            "prob:0.5@",
            "prob:0.5@x",
            "prob:0.5@-1",
            "prob:1.5",
            "prob:-0.2",
            "prob:NaN",
            "prob:inf",
        ];
        let bad = Gen::new(|r: &mut crate::util::rng::Rng| BADS[r.below(BADS.len())].to_string());
        check("faultinject.invalid_specs_reject", &bad, |trig| {
            // Through the full spec surface (parse + range validation in
            // `arm`): an Err, never a panic, and the registry untouched.
            match arm_spec(&format!("test.point={trig}")) {
                Ok(()) => return Err(format!("'{trig}' was accepted")),
                Err(msg) if msg.is_empty() => return Err("empty error message".into()),
                Err(_) => {}
            }
            if enabled() || !status().is_empty() {
                disarm_all();
                return Err(format!("failed arm of '{trig}' left state behind"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_arbitrary_trigger_text_never_panics_the_parser() {
        use crate::util::prop::{check, Gen};
        let junk = Gen::new(|r: &mut crate::util::rng::Rng| -> String {
            let len = r.below(12);
            (0..len)
                .map(|_| {
                    // Bias towards grammar-adjacent characters so the fuzz
                    // walks the parser's edges, not just its front door.
                    const ALPHA: &[u8] = b"oncevry:[email protected] \t-+eE";
                    ALPHA[r.below(ALPHA.len())] as char
                })
                .collect()
        });
        check("faultinject.parser_total", &junk, |s| {
            if let Ok((trig, _)) = parse_trigger(s) {
                // Whatever parses must re-parse from its display form.
                parse_trigger(&trig.to_string())
                    .map_err(|e| format!("'{s}' parsed to '{trig}' which rejects: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_prob_firing_replays_exactly_for_a_seed() {
        use crate::util::prop::{check2, f32_in, Gen};
        let _l = lock();
        let seeds = Gen::new(|r: &mut crate::util::rng::Rng| r.next_u64());
        check2("faultinject.prob_seed_replay", &f32_in(0.05, 0.95), &seeds, |p, seed| {
            let run = || -> Result<Vec<bool>, String> {
                let _g = guard(&format!("test.point=prob:{p}@{seed}"))?;
                Ok((0..256).map(|_| fire(TEST_POINT)).collect())
            };
            let (a, b) = (run()?, run()?);
            if a != b {
                return Err(format!("prob:{p}@{seed} did not replay identically"));
            }
            Ok(())
        });
    }

    #[test]
    fn multi_point_spec_arms_every_part() {
        let _l = lock();
        let _g = guard("test.point=prob:1@3, test.point=every:2").unwrap();
        // Later parts replace earlier arms of the same point.
        let st = status();
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].trigger, "every:2");
        assert!(!fire(TEST_POINT));
        assert!(fire(TEST_POINT));
    }
}
