//! The embedding service: submits jobs onto worker threads, multiplexes
//! them over one shared PJRT runtime, exposes status / snapshots / stop /
//! wait. This is the process-lifetime object behind both the CLI and the
//! TCP server.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::runtime::Runtime;

use super::job::{JobPhase, JobSpec, Snapshot};
use super::pipeline::{run_pipeline_cached, JobResult};
use super::progress::JobState;
use super::simcache::SimilarityCache;

/// Similarity-cache capacity: distinct `(dataset, knn, k, perplexity,
/// seed)` combinations kept hot. P matrices are O(N·k) f32 — at the
/// paper's defaults a 100k-point entry is ~100 MB, so keep few.
const SIM_CACHE_CAPACITY: usize = 8;

pub type JobId = u64;

struct JobEntry {
    state: JobState,
    handle: Option<std::thread::JoinHandle<()>>,
    result: Arc<Mutex<Option<anyhow::Result<JobResult>>>>,
    spec: JobSpec,
}

/// Multiplexes embedding jobs over a shared (optional) PJRT runtime.
pub struct EmbeddingService {
    runtime: Option<Arc<Runtime>>,
    jobs: Mutex<HashMap<JobId, JobEntry>>,
    next_id: std::sync::atomic::AtomicU64,
    /// Cap on concurrently *running* optimisations (simple admission
    /// control; kNN stages are already parallel internally).
    semaphore: Arc<(Mutex<usize>, std::sync::Condvar)>,
    max_concurrent: usize,
    /// Shared similarity cache: repeated jobs over the same dataset and
    /// kNN/perplexity parameters skip straight to optimisation.
    sim_cache: Arc<SimilarityCache>,
}

impl EmbeddingService {
    pub fn new(runtime: Option<Arc<Runtime>>, max_concurrent: usize) -> Self {
        Self {
            runtime,
            jobs: Mutex::new(HashMap::new()),
            next_id: std::sync::atomic::AtomicU64::new(1),
            semaphore: Arc::new((Mutex::new(0), std::sync::Condvar::new())),
            max_concurrent: max_concurrent.max(1),
            sim_cache: Arc::new(SimilarityCache::new(SIM_CACHE_CAPACITY)),
        }
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// The service-wide similarity cache (stats/tests).
    pub fn sim_cache(&self) -> &SimilarityCache {
        &self.sim_cache
    }

    /// Submit a job; returns immediately with its id.
    pub fn submit(&self, spec: JobSpec) -> JobId {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let state = JobState::default();
        let result: Arc<Mutex<Option<anyhow::Result<JobResult>>>> = Arc::new(Mutex::new(None));
        let rt = self.runtime.clone();
        let st = state.clone();
        let res = result.clone();
        let sem = self.semaphore.clone();
        let max = self.max_concurrent;
        let spec2 = spec.clone();
        let cache = self.sim_cache.clone();
        let handle = std::thread::spawn(move || {
            // Admission control.
            {
                let (lock, cv) = &*sem;
                let mut running = lock.lock().unwrap();
                while *running >= max {
                    running = cv.wait(running).unwrap();
                }
                *running += 1;
            }
            let out = run_pipeline_cached(&spec2, rt, &st, Some(&cache));
            if let Err(e) = &out {
                st.set_phase(JobPhase::Failed(format!("{e:#}")));
            }
            *res.lock().unwrap() = Some(out);
            let (lock, cv) = &*sem;
            *lock.lock().unwrap() -= 1;
            cv.notify_one();
        });
        self.jobs
            .lock()
            .unwrap()
            .insert(id, JobEntry { state, handle: Some(handle), result, spec });
        id
    }

    pub fn phase(&self, id: JobId) -> Option<JobPhase> {
        self.jobs.lock().unwrap().get(&id).map(|j| j.state.phase())
    }

    pub fn spec(&self, id: JobId) -> Option<JobSpec> {
        self.jobs.lock().unwrap().get(&id).map(|j| j.spec.clone())
    }

    pub fn latest_snapshot(&self, id: JobId) -> Option<Snapshot> {
        self.jobs.lock().unwrap().get(&id).and_then(|j| j.state.latest_snapshot())
    }

    /// Subscribe to a job's snapshot stream.
    pub fn subscribe(&self, id: JobId) -> Option<std::sync::mpsc::Receiver<Snapshot>> {
        self.jobs.lock().unwrap().get(&id).map(|j| j.state.snapshots.subscribe())
    }

    /// Request user-driven early termination.
    pub fn stop(&self, id: JobId) -> bool {
        if let Some(j) = self.jobs.lock().unwrap().get(&id) {
            j.state.request_stop();
            true
        } else {
            false
        }
    }

    /// Block until the job finishes; returns its result.
    pub fn wait(&self, id: JobId) -> anyhow::Result<JobResult> {
        let handle = {
            let mut jobs = self.jobs.lock().unwrap();
            let j = jobs.get_mut(&id).ok_or_else(|| anyhow::anyhow!("unknown job {id}"))?;
            j.handle.take()
        };
        if let Some(h) = handle {
            h.join().map_err(|_| anyhow::anyhow!("job thread panicked"))?;
        }
        let jobs = self.jobs.lock().unwrap();
        let j = jobs.get(&id).ok_or_else(|| anyhow::anyhow!("unknown job {id}"))?;
        let mut slot = j.result.lock().unwrap();
        slot.take().ok_or_else(|| anyhow::anyhow!("job {id} result already taken"))?
    }

    /// All known job ids with their phases.
    pub fn list(&self) -> Vec<(JobId, JobPhase)> {
        let mut v: Vec<_> =
            self.jobs.lock().unwrap().iter().map(|(id, j)| (*id, j.state.phase())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::KnnMethod;
    use crate::embed::OptParams;

    fn tiny_spec(iters: usize) -> JobSpec {
        JobSpec {
            dataset: "gaussians".into(),
            n: 100,
            engine: "bh-0.5".into(),
            perplexity: 8.0,
            knn: KnnMethod::Brute,
            params: OptParams { iters, exaggeration_iters: 10, ..Default::default() },
            snapshot_every: 5,
            auto_stop: None,
            seed: 1,
        }
    }

    #[test]
    fn submit_wait_roundtrip() {
        let svc = EmbeddingService::new(None, 2);
        let id = svc.submit(tiny_spec(30));
        let res = svc.wait(id).unwrap();
        assert_eq!(res.embedding.len(), 200);
        assert_eq!(svc.phase(id), Some(JobPhase::Done));
    }

    #[test]
    fn concurrent_jobs_complete() {
        let svc = Arc::new(EmbeddingService::new(None, 2));
        let ids: Vec<_> = (0..4).map(|_| svc.submit(tiny_spec(20))).collect();
        for id in ids {
            let res = svc.wait(id).unwrap();
            assert!(res.embedding.iter().all(|v| v.is_finite()));
        }
        assert_eq!(svc.list().len(), 4);
    }

    #[test]
    fn stop_mid_flight() {
        let svc = EmbeddingService::new(None, 1);
        let id = svc.submit(tiny_spec(5000));
        let rx = svc.subscribe(id).unwrap();
        let _ = rx.recv(); // first snapshot = job is running
        assert!(svc.stop(id));
        let res = svc.wait(id).unwrap();
        assert!(res.stopped_early);
        assert_eq!(svc.phase(id), Some(JobPhase::Stopped));
    }

    #[test]
    fn repeated_jobs_hit_the_similarity_cache() {
        let svc = EmbeddingService::new(None, 2);
        let a = svc.submit(tiny_spec(20));
        let ra = svc.wait(a).unwrap();
        assert!(!ra.timings.sim_cache_hit);
        let b = svc.submit(tiny_spec(20));
        let rb = svc.wait(b).unwrap();
        assert!(rb.timings.sim_cache_hit, "identical resubmission must hit");
        assert_eq!(ra.embedding, rb.embedding);
        assert_eq!(svc.sim_cache().stats(), (1, 1));
        assert_eq!(svc.sim_cache().len(), 1);
    }

    #[test]
    fn failed_job_reports_phase() {
        let svc = EmbeddingService::new(None, 1);
        let mut spec = tiny_spec(5);
        spec.dataset = "no-such-dataset".into();
        let id = svc.submit(spec);
        assert!(svc.wait(id).is_err());
        assert!(matches!(svc.phase(id), Some(JobPhase::Failed(_))));
    }

    #[test]
    fn unknown_job_is_none() {
        let svc = EmbeddingService::new(None, 1);
        assert!(svc.phase(999).is_none());
        assert!(!svc.stop(999));
    }
}
