//! The embedding service: a cooperatively scheduled pool of
//! `max_concurrent` workers time-slicing every active embedding session,
//! multiplexed over one shared PJRT runtime. This is the process-lifetime
//! object behind both the CLI and the TCP server.
//!
//! Jobs are not threads. A submitted job becomes a [`JobTask`] — the
//! similarity stage plus a live [`EmbeddingSession`] — and enters the
//! two-class ready queue ([`ReadyQueue`]): round-robin within a
//! [`super::job::Priority`] class, weighted between classes so
//! `interactive` jobs take quanta ahead of `batch` work under contention
//! (one batch pop per [`BATCH_POP_PERIOD`] while both classes wait)
//! without ever starving batch. Workers pop a job, run **one quantum**
//! (at most [`MAX_QUANTUM_STEPS`] gradient-descent steps or
//! [`QUANTUM_MS`] milliseconds, whichever comes first), publish a live
//! snapshot straight from the session state, and re-enqueue the job at
//! the back of its class — so a 100k-point job cannot starve ten
//! 2k-point jobs the way run-to-completion workers did. Between quanta
//! the scheduler honours the job's control surface: `stop` finalises,
//! `pause` parks the task (session state intact, caches warm),
//! `resume` re-enqueues it, and pending [`ParamUpdate`]s are applied to
//! the session — live re-parameterisation mid-optimisation.
//!
//! With [`ServiceConfig::state_dir`] the service is **durable**: every
//! running session's checkpoint is journalled into the state dir at the
//! configured iteration interval (`coordinator::store::JobJournal`), the
//! similarity store persists to disk, and a restarted service re-admits
//! every journalled job as *resumable* — it continues from its last
//! checkpoint instead of being lost, under the same job id.
//!
//! The service **degrades before it dies**: [`EmbeddingService::try_submit`]
//! sheds work with a retriable error once the ready queue passes
//! [`ServiceConfig::max_queue_depth`] (or while draining), and
//! [`EmbeddingService::drain`] implements graceful shutdown — stop
//! admitting, park + journal every live session at its next step
//! boundary through the ordinary pause machinery, stop the workers — so
//! a restart resumes every job bit-identically. A worker that panics
//! mid-step (including via the `engine.step_panic` fault point) fails
//! only its own job.

use std::collections::{HashMap, VecDeque};
use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::embed::{Checkpoint, EmbeddingSession, IterStats};
use crate::obs;
use crate::runtime::Runtime;
use crate::util::json::{self, Json};
use crate::util::timer::Stopwatch;

use super::faultinject;
use super::job::{JobPhase, JobSpec, ParamUpdate, Priority, Snapshot};
use super::pipeline::{self, AutoStopTracker, JobResult, StageTimings};
use super::progress::{JobState, Subscription};
use super::simcache::SimilarityCache;
use super::store::JobJournal;

/// Similarity-cache capacity: distinct `(dataset, knn, k, perplexity,
/// seed)` combinations kept hot. P matrices are O(N·k) f32 — at the
/// paper's defaults a 100k-point entry is ~100 MB, so keep few.
const SIM_CACHE_CAPACITY: usize = 8;

/// Time-slice budget per scheduler quantum. Long enough to amortise the
/// queue round-trip, short enough that ten interactive jobs sharing two
/// workers each see fresh snapshots several times a second.
const QUANTUM_MS: u64 = 25;

/// Step cap per quantum — keeps tiny problems (sub-millisecond steps)
/// from monopolising a worker for a full time slice anyway.
const MAX_QUANTUM_STEPS: usize = 64;

/// Refresh floor for the `latest` snapshot when nobody is subscribed to
/// the stream: the `snapshot` command stays live to within this interval
/// without paying a full positions copy every quantum. Subscribers (and
/// pause/finalise boundaries) always get an immediate publish.
const IDLE_SNAPSHOT_MS: u64 = 100;

/// Default admission cap: ready-queue depth beyond which
/// [`EmbeddingService::try_submit`] sheds new work.
const MAX_QUEUE_DEPTH: usize = 256;

/// Inter-class weighting of the ready queue: while both classes have
/// runnable jobs, one pop in this many goes to `batch`, the rest to
/// `interactive` — a 3:1 quantum split that keeps interactive users
/// responsive under batch load yet guarantees batch forward progress.
const BATCH_POP_PERIOD: u64 = 4;

/// The scheduler's two-class ready queue: FIFO round-robin within a
/// [`Priority`] class, [`BATCH_POP_PERIOD`]-weighted interleave between
/// classes under contention, plain FIFO when only one class has work.
#[derive(Default)]
struct ReadyQueue {
    interactive: VecDeque<JobId>,
    batch: VecDeque<JobId>,
    /// Monotonic pop counter driving the weighted interleave.
    pops: u64,
}

impl ReadyQueue {
    fn len(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }

    fn push(&mut self, id: JobId, priority: Priority) {
        match priority {
            Priority::Interactive => self.interactive.push_back(id),
            Priority::Batch => self.batch.push_back(id),
        }
    }

    fn pop(&mut self) -> Option<JobId> {
        let take_batch = match (self.interactive.is_empty(), self.batch.is_empty()) {
            (true, _) => true,
            (false, true) => false,
            // Contention: the weighted interleave decides.
            (false, false) => self.pops % BATCH_POP_PERIOD == BATCH_POP_PERIOD - 1,
        };
        let id = if take_batch {
            self.batch.pop_front()
        } else {
            self.interactive.pop_front()
        };
        if id.is_some() {
            self.pops += 1;
        }
        id
    }
}

pub type JobId = u64;

/// Why [`EmbeddingService::try_submit`] shed a job. Both variants are
/// *retriable states of the service*, not properties of the job — the
/// client should back off and resubmit (or find another instance).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The ready queue is at [`ServiceConfig::max_queue_depth`].
    QueueFull { depth: usize, cap: usize },
    /// The service is drain-shutting-down and admits nothing new.
    Draining,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { depth, cap } => {
                write!(f, "ready queue full ({depth} >= cap {cap}); retry later")
            }
            SubmitError::Draining => write!(f, "service is draining for shutdown; retry elsewhere"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Service-construction knobs (see [`EmbeddingService::with_config`]).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker-pool size (concurrent step quanta).
    pub max_concurrent: usize,
    /// Durable-state directory: checkpoint journal under `jobs/`, the
    /// on-disk similarity store under `simstore/`. `None` = in-memory
    /// service (the previous behaviour).
    pub state_dir: Option<PathBuf>,
    /// Journal a running session's checkpoint every this many
    /// iterations (clamped to ≥ 1; pause/park always journals).
    pub journal_every: usize,
    /// Ready entries kept per similarity-store level.
    pub sim_cache_capacity: usize,
    /// Per-thread trace-ring capacity, in span events (`serve
    /// --trace-ring`). Applied process-wide at construction; threads
    /// that already emitted events keep their existing rings.
    pub trace_ring: usize,
    /// Admission cap: [`EmbeddingService::try_submit`] sheds with a
    /// retriable [`SubmitError::QueueFull`] once the ready queue holds
    /// this many jobs (clamped to ≥ 1).
    pub max_queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_concurrent: 2,
            state_dir: None,
            journal_every: 50,
            sim_cache_capacity: SIM_CACHE_CAPACITY,
            trace_ring: obs::trace::DEFAULT_RING_CAPACITY,
            max_queue_depth: MAX_QUEUE_DEPTH,
        }
    }
}

/// A job's live optimisation state, owned by whichever worker is
/// currently driving it (or parked in the entry's slot between quanta).
struct JobTask {
    spec: JobSpec,
    /// Labels from the dataset (carried into the final [`JobResult`]).
    labels: Vec<u8>,
    timings: StageTimings,
    /// None until the prepare stage (dataset + kNN + P + `begin`) ran.
    session: Option<Box<dyn EmbeddingSession>>,
    auto: AutoStopTracker,
    iters_run: usize,
    last_kl: f64,
    /// When the last snapshot was published (idle-throttling).
    last_snapshot: Option<Stopwatch>,
    /// Iteration count at the last journal write (durable services).
    last_journal_iter: usize,
    /// Running while the task sits parked after a pause; read at the
    /// first post-resume slice (the `scheduler.park_resume_ns` metric
    /// and the `scheduler.park` trace span).
    parked: Option<Stopwatch>,
}

/// Rendezvous for `checkpoint` requests: a client flags `pending`, the
/// driving worker captures the session state at its next step boundary
/// and posts it into `ready`.
#[derive(Default)]
struct CkptSlot {
    pending: bool,
    ready: Option<Checkpoint>,
}

/// Scheduler metrics: cached handles into a **service-local**
/// [`obs::Registry`]. Tests run services in parallel, so the scheduler
/// cannot share the process-global registry without mixing counts; the
/// `metrics` protocol command merges this registry with the global one.
struct SchedMetrics {
    registry: Arc<obs::Registry>,
    /// `scheduler.queue_depth` — ready-queue length after each push/pop.
    queue_depth: Arc<obs::Gauge>,
    /// `scheduler.quantum_ns` — wall time of every step quantum, vs.
    /// the [`QUANTUM_MS`] budget.
    quantum_ns: Arc<obs::Histogram>,
    /// `scheduler.quantum_steps` — steps run per quantum.
    quantum_steps: Arc<obs::Histogram>,
    /// `scheduler.quantum_overruns` — quanta that ran ≥ 2× the budget.
    /// The loop checks the clock only between steps, so finishing a
    /// little past [`QUANTUM_MS`] is by design; an overrun means one
    /// non-preemptible step ate the whole slice.
    overruns: Arc<obs::Counter>,
    /// `scheduler.park_resume_ns` — pause-park to next-slice latency.
    park_resume_ns: Arc<obs::Histogram>,
    /// `scheduler.submits_shed` — submits rejected by admission control
    /// (queue at cap, or draining).
    submits_shed: Arc<obs::Counter>,
    /// `scheduler.quanta_interactive` / `scheduler.quanta_batch` —
    /// quanta granted per scheduling class; under contention the ratio
    /// tracks [`BATCH_POP_PERIOD`], the fairness-class guarantee made
    /// observable.
    quanta_interactive: Arc<obs::Counter>,
    quanta_batch: Arc<obs::Counter>,
    /// `scheduler.draining` — 1 once drain shutdown began.
    draining_gauge: Arc<obs::Gauge>,
    /// `engine.attr_ns` / `engine.rep_ns` / `engine.grad_ns` — per-step
    /// phase breakdown carried on [`IterStats`] (zero samples when
    /// [`obs::enabled`] is off or the engine's step is fused).
    attr_ns: Arc<obs::Histogram>,
    rep_ns: Arc<obs::Histogram>,
    grad_ns: Arc<obs::Histogram>,
}

impl SchedMetrics {
    fn new() -> Self {
        let registry = Arc::new(obs::Registry::new());
        Self {
            queue_depth: registry.gauge("scheduler.queue_depth"),
            quantum_ns: registry.histogram("scheduler.quantum_ns"),
            quantum_steps: registry.histogram("scheduler.quantum_steps"),
            overruns: registry.counter("scheduler.quantum_overruns"),
            park_resume_ns: registry.histogram("scheduler.park_resume_ns"),
            submits_shed: registry.counter("scheduler.submits_shed"),
            quanta_interactive: registry.counter("scheduler.quanta_interactive"),
            quanta_batch: registry.counter("scheduler.quanta_batch"),
            draining_gauge: registry.gauge("scheduler.draining"),
            attr_ns: registry.histogram("engine.attr_ns"),
            rep_ns: registry.histogram("engine.rep_ns"),
            grad_ns: registry.histogram("engine.grad_ns"),
            registry,
        }
    }
}

/// Per-job scheduling counters (relaxed atomics, written by the driving
/// worker, read by the `metrics` command's per-job summary).
#[derive(Default)]
struct JobObs {
    quanta: AtomicU64,
    steps: AtomicU64,
    overruns: AtomicU64,
    attr_ns: AtomicU64,
    rep_ns: AtomicU64,
    grad_ns: AtomicU64,
}

struct JobEntry {
    spec: JobSpec,
    state: JobState,
    obs: JobObs,
    /// The task, parked between quanta. `None` while a worker drives it
    /// or after the job finished.
    task: Mutex<Option<JobTask>>,
    /// Terminal result (`Err` keeps the message only — clonable, so any
    /// number of clients can `wait` on the same job).
    result: Mutex<Option<Result<JobResult, String>>>,
    done_cv: Condvar,
    ckpt: Mutex<CkptSlot>,
    ckpt_cv: Condvar,
}

/// State shared between the service handle and its workers.
struct ServiceInner {
    runtime: Option<Arc<Runtime>>,
    jobs: Mutex<HashMap<JobId, Arc<JobEntry>>>,
    queue: Mutex<ReadyQueue>,
    queue_cv: Condvar,
    shutdown: AtomicBool,
    /// Drain shutdown in progress: admission sheds, workers keep running
    /// until every live session is parked + journalled.
    draining: AtomicBool,
    /// Admission cap for [`EmbeddingService::try_submit`].
    max_queue_depth: usize,
    sim_cache: Arc<SimilarityCache>,
    /// Checkpoint journal (durable services only).
    journal: Option<JobJournal>,
    journal_every: usize,
    metrics: SchedMetrics,
}

impl ServiceInner {
    fn enqueue(&self, id: JobId, priority: Priority) {
        let mut queue = self.queue.lock().unwrap();
        queue.push(id, priority);
        self.metrics.queue_depth.set(queue.len() as i64);
        self.queue_cv.notify_one();
    }

    /// Register a job under an explicit id and make it runnable — the
    /// shared tail of `submit` and journal re-admission.
    fn admit(&self, id: JobId, spec: JobSpec) {
        // Durable services journal the job at admission, before any
        // iteration runs: a service killed in the (potentially long)
        // similarity stage must still re-admit the job on restart. The
        // record carries the submit's own resume blob when present —
        // repeated kill/restart cycles keep resuming from the same
        // checkpoint until the scheduler journals a fresher one.
        if let Some(journal) = &self.journal {
            let mut jspec = spec.clone();
            let ckpt = jspec.resume_from.take().unwrap_or_default();
            let spec_json = super::protocol::spec_to_json(&jspec).to_string();
            journal.write(id, &spec_json, &ckpt);
        }
        let task = JobTask {
            spec: spec.clone(),
            labels: Vec::new(),
            timings: StageTimings::default(),
            session: None,
            auto: AutoStopTracker::new(spec.auto_stop, spec.params.exaggeration_iters),
            iters_run: 0,
            last_kl: f64::NAN,
            last_snapshot: None,
            last_journal_iter: 0,
            parked: None,
        };
        let entry = Arc::new(JobEntry {
            spec,
            state: JobState::default(),
            obs: JobObs::default(),
            task: Mutex::new(Some(task)),
            result: Mutex::new(None),
            done_cv: Condvar::new(),
            ckpt: Mutex::new(CkptSlot::default()),
            ckpt_cv: Condvar::new(),
        });
        let priority = entry.spec.priority;
        self.jobs.lock().unwrap().insert(id, entry);
        self.enqueue(id, priority);
    }
}

/// What a worker does with the task after one scheduling slice.
enum SliceOutcome {
    /// More steps to run — back of the ready queue.
    Requeue,
    /// Paused — park until `resume` (or `stop`) re-enqueues it.
    Park,
    /// Terminal (done, stopped, failed) — result is set.
    Finished,
}

/// Multiplexes embedding jobs over a shared (optional) PJRT runtime.
pub struct EmbeddingService {
    inner: Arc<ServiceInner>,
    next_id: AtomicU64,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl EmbeddingService {
    pub fn new(runtime: Option<Arc<Runtime>>, max_concurrent: usize) -> Self {
        Self::with_config(runtime, ServiceConfig { max_concurrent, ..Default::default() })
    }

    /// Construct a service from a full [`ServiceConfig`]. With a
    /// `state_dir`, journalled jobs from a previous process are
    /// **re-admitted** (same ids, resuming from their last checkpoint)
    /// before the worker pool starts, and the similarity store opens its
    /// on-disk level.
    pub fn with_config(runtime: Option<Arc<Runtime>>, cfg: ServiceConfig) -> Self {
        obs::trace::set_ring_capacity(cfg.trace_ring);
        // Resolve the SIMD dispatch tier up front (first use would do it
        // lazily anyway) and pin it in the global registry so `metrics`
        // consumers see which kernels this process is serving with.
        obs::registry()
            .gauge("simd.tier_id")
            .set(crate::util::simd::active_tier() as i64);
        let (sim_cache, journal) = match &cfg.state_dir {
            Some(dir) => {
                let cache =
                    SimilarityCache::with_disk(cfg.sim_cache_capacity, &dir.join("simstore"));
                let journal = match JobJournal::open(&dir.join("jobs")) {
                    Ok(j) => Some(j),
                    Err(e) => {
                        eprintln!(
                            "warning: state dir {} unusable for journaling ({e}); \
                             jobs will not survive restarts",
                            dir.display()
                        );
                        None
                    }
                };
                (cache, journal)
            }
            None => (SimilarityCache::new(cfg.sim_cache_capacity), None),
        };
        let inner = Arc::new(ServiceInner {
            runtime,
            jobs: Mutex::new(HashMap::new()),
            queue: Mutex::new(ReadyQueue::default()),
            queue_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            max_queue_depth: cfg.max_queue_depth.max(1),
            sim_cache: Arc::new(sim_cache),
            journal,
            journal_every: cfg.journal_every.max(1),
            metrics: SchedMetrics::new(),
        });
        // Re-admit interrupted jobs before any worker can race the scan.
        let mut max_id = 0u64;
        if let Some(j) = &inner.journal {
            for entry in j.read_all() {
                let spec = json::parse(&entry.spec_json)
                    .map_err(anyhow::Error::from)
                    .and_then(|v| super::protocol::spec_from_json(&v));
                match spec {
                    Ok(mut spec) => {
                        // An admit-time record journalled before the
                        // first checkpoint carries an empty blob: the
                        // job restarts from scratch (deterministically
                        // reproducing the lost iterations).
                        if !entry.checkpoint.is_empty() {
                            spec.resume_from = Some(entry.checkpoint);
                        }
                        eprintln!(
                            "re-admitting journalled job {} ({} n={} engine={})",
                            entry.id, spec.dataset, spec.n, spec.engine
                        );
                        inner.admit(entry.id, spec);
                        max_id = max_id.max(entry.id);
                    }
                    Err(e) => {
                        eprintln!(
                            "warning: journalled job {} unreadable ({e:#}); dropped",
                            entry.id
                        );
                        j.remove(entry.id);
                    }
                }
            }
        }
        let workers = (0..cfg.max_concurrent.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        Self { inner, next_id: AtomicU64::new(max_id + 1), workers: Mutex::new(workers) }
    }

    pub fn has_runtime(&self) -> bool {
        self.inner.runtime.is_some()
    }

    /// The service-wide similarity cache (stats/tests).
    pub fn sim_cache(&self) -> &SimilarityCache {
        &self.inner.sim_cache
    }

    /// Whether this service journals checkpoints to a state dir.
    pub fn is_durable(&self) -> bool {
        self.inner.journal.is_some()
    }

    /// Submit a job; returns immediately with its id. In-process
    /// callers (CLI, tests) bypass admission control — use
    /// [`Self::try_submit`] on serving paths that must shed load.
    pub fn submit(&self, spec: JobSpec) -> JobId {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.inner.admit(id, spec);
        id
    }

    /// [`Self::submit`] behind admission control: sheds with a
    /// retriable [`SubmitError`] when the ready queue is at
    /// [`ServiceConfig::max_queue_depth`] or the service is draining.
    /// The TCP `submit` command routes through here.
    pub fn try_submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        if self.inner.draining.load(Ordering::SeqCst) {
            self.inner.metrics.submits_shed.inc();
            return Err(SubmitError::Draining);
        }
        let depth = self.inner.queue.lock().unwrap().len();
        if depth >= self.inner.max_queue_depth {
            self.inner.metrics.submits_shed.inc();
            return Err(SubmitError::QueueFull { depth, cap: self.inner.max_queue_depth });
        }
        Ok(self.submit(spec))
    }

    /// Graceful drain shutdown (the TCP `shutdown` command and the
    /// SIGTERM handler): stop admitting, ask every live job to park at
    /// its next step boundary — parking journals the session, exactly
    /// like a user `pause` — wait (bounded by `timeout`) for the parks,
    /// then stop the worker pool. Returns the number of live jobs left
    /// parked (each re-admittable: a restarted service resumes them
    /// bit-identically from their journalled checkpoints). A job stuck
    /// in a non-preemptible stage past the timeout still restarts from
    /// its admission-time journal record.
    pub fn drain(&self, timeout: std::time::Duration) -> usize {
        self.inner.draining.store(true, Ordering::SeqCst);
        self.inner.metrics.draining_gauge.set(1);
        let ids: Vec<JobId> = self.inner.jobs.lock().unwrap().keys().copied().collect();
        for id in &ids {
            if let Some(e) = self.entry(*id) {
                if !e.state.phase().is_terminal() {
                    e.state.request_pause();
                }
            }
        }
        let sw = Stopwatch::start();
        loop {
            let undrained = ids
                .iter()
                .filter(|&&id| match self.entry(id) {
                    // Parked (task slot occupied) or terminal = drained;
                    // a task a worker still drives = not yet.
                    Some(e) => {
                        !e.state.phase().is_terminal() && e.task.lock().unwrap().is_none()
                    }
                    None => false,
                })
                .count();
            if undrained == 0 || sw.expired(timeout) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        ids.iter()
            .filter(|&&id| self.phase(id).is_some_and(|p| !p.is_terminal()))
            .count()
    }

    /// True once [`Self::drain`] has begun.
    pub fn is_draining(&self) -> bool {
        self.inner.draining.load(Ordering::SeqCst)
    }

    /// Snapshot the job's full optimiser state (the TCP `checkpoint`
    /// command). A parked (paused/queued-between-quanta) session is
    /// captured directly; a session a worker is driving is captured *by
    /// the worker* at its next step boundary (a rendezvous, not a poll —
    /// the parked window between back-to-back quanta is microseconds, so
    /// polling the task slot would race). Errors if the job is terminal
    /// or its optimiser state does not exist yet (similarity stage still
    /// running, or queued behind it).
    pub fn checkpoint(&self, id: JobId) -> anyhow::Result<Checkpoint> {
        let entry = self.entry(id).ok_or_else(|| anyhow::anyhow!("unknown job {id}"))?;
        let sw = Stopwatch::start();
        loop {
            anyhow::ensure!(
                !entry.state.phase().is_terminal(),
                "job {id} already finished — fetch its result via wait/snapshot"
            );
            {
                let guard = entry.task.lock().unwrap();
                if let Some(task) = guard.as_ref() {
                    if let Some(session) = task.session.as_ref() {
                        return Ok(session.checkpoint());
                    }
                    // Parked before the similarity stage ran. A job
                    // submitted with resume_from already *is* a
                    // checkpoint; anything else has no state yet.
                    if let Some(bytes) = &task.spec.resume_from {
                        return Checkpoint::from_bytes(bytes);
                    }
                    anyhow::bail!(
                        "job {id} has no optimiser state yet (queued or in the similarity stage)"
                    );
                }
            }
            // A worker is driving the task: ask it to capture at the
            // next boundary and wait for the hand-off. Clear any stale
            // capture a previous (timed-out) request left behind first.
            let mut slot = entry.ckpt.lock().unwrap();
            slot.ready = None;
            slot.pending = true;
            while slot.ready.is_none() {
                let (s, timeout) = entry
                    .ckpt_cv
                    .wait_timeout(slot, std::time::Duration::from_millis(50))
                    .unwrap();
                slot = s;
                if slot.ready.is_some() {
                    break;
                }
                // The job may have finalised (or parked pre-begin) while
                // we waited — fall back to the outer loop to re-inspect.
                if timeout.timed_out() {
                    break;
                }
            }
            if let Some(ck) = slot.ready.take() {
                return Ok(ck);
            }
            slot.pending = false;
            drop(slot);
            anyhow::ensure!(
                !sw.expired(std::time::Duration::from_secs(30)),
                "timed out waiting for job {id}'s step boundary"
            );
        }
    }

    fn entry(&self, id: JobId) -> Option<Arc<JobEntry>> {
        self.inner.jobs.lock().unwrap().get(&id).cloned()
    }

    pub fn phase(&self, id: JobId) -> Option<JobPhase> {
        self.entry(id).map(|e| e.state.phase())
    }

    pub fn spec(&self, id: JobId) -> Option<JobSpec> {
        self.entry(id).map(|e| e.spec.clone())
    }

    pub fn latest_snapshot(&self, id: JobId) -> Option<Snapshot> {
        self.entry(id).and_then(|e| e.state.latest_snapshot())
    }

    /// Subscribe to a job's snapshot stream (bounded queue: drop-oldest
    /// under backpressure, eviction if chronically slow — see
    /// [`super::progress::Broadcast`]).
    pub fn subscribe(&self, id: JobId) -> Option<Subscription<Snapshot>> {
        self.entry(id).map(|e| e.state.snapshots.subscribe())
    }

    /// Request user-driven early termination. Also wakes a paused job so
    /// it can finalise.
    pub fn stop(&self, id: JobId) -> bool {
        let Some(e) = self.entry(id) else {
            return false;
        };
        e.state.request_stop();
        self.inner.enqueue(id, e.spec.priority);
        true
    }

    /// Park the job at its next step boundary (no-op once terminal).
    /// The session — optimiser state, engine caches, device tensors —
    /// stays alive; `resume` picks up exactly where it stopped.
    pub fn pause(&self, id: JobId) -> bool {
        match self.entry(id) {
            Some(e) if !e.state.phase().is_terminal() => {
                e.state.request_pause();
                true
            }
            _ => false,
        }
    }

    /// Re-enter a paused job into the scheduler.
    pub fn resume(&self, id: JobId) -> bool {
        match self.entry(id) {
            Some(e) if !e.state.phase().is_terminal() => {
                e.state.clear_pause();
                self.inner.enqueue(id, e.spec.priority);
                true
            }
            _ => false,
        }
    }

    /// Queue a live hyperparameter update; the scheduler applies it to
    /// the session at the next step boundary.
    pub fn update(&self, id: JobId, update: ParamUpdate) -> bool {
        match self.entry(id) {
            Some(e) if !e.state.phase().is_terminal() => {
                e.state.push_update(update);
                true
            }
            _ => false,
        }
    }

    /// Block until the job finishes; returns (a clone of) its result.
    pub fn wait(&self, id: JobId) -> anyhow::Result<JobResult> {
        let entry = self.entry(id).ok_or_else(|| anyhow::anyhow!("unknown job {id}"))?;
        let mut slot = entry.result.lock().unwrap();
        while slot.is_none() {
            slot = entry.done_cv.wait(slot).unwrap();
        }
        match slot.as_ref().unwrap() {
            Ok(res) => Ok(res.clone()),
            Err(msg) => Err(anyhow::anyhow!("{msg}")),
        }
    }

    /// All known job ids with their phases.
    pub fn list(&self) -> Vec<(JobId, JobPhase)> {
        let mut v: Vec<_> = self
            .inner
            .jobs
            .lock()
            .unwrap()
            .iter()
            .map(|(id, e)| (*id, e.state.phase()))
            .collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Merged metrics snapshot — what the TCP `metrics` command and
    /// `serve --metrics-dump` emit. Five sections: `service` (the
    /// scheduler's own registry: quantum histograms, queue depth,
    /// overruns, park→resume latency, per-phase engine timings),
    /// `global` (the process-wide registry: store I/O, snapshot
    /// fanout), `sim_cache` (two-level hit/miss/coalesce/evict
    /// counters), `jobs` (a per-job scheduling summary), and `simd`
    /// (the resolved CPU-feature dispatch tier, see
    /// [`crate::util::simd`]).
    pub fn metrics_json(&self) -> Json {
        let cache = &self.inner.sim_cache;
        let mut sim = cache.p_stats().to_json_fields("p");
        sim.extend(cache.graph_stats().to_json_fields("graph"));
        let jobs: Vec<Json> = {
            let jobs = self.inner.jobs.lock().unwrap();
            let mut ids: Vec<JobId> = jobs.keys().copied().collect();
            ids.sort_unstable();
            ids.iter()
                .map(|id| {
                    let e = &jobs[id];
                    let o = &e.obs;
                    let secs = |ns: &AtomicU64| ns.load(Ordering::Relaxed) as f64 / 1e9;
                    Json::obj(vec![
                        ("job", Json::Num(*id as f64)),
                        ("phase", Json::Str(e.state.phase().label())),
                        ("quanta", Json::Num(o.quanta.load(Ordering::Relaxed) as f64)),
                        ("steps", Json::Num(o.steps.load(Ordering::Relaxed) as f64)),
                        ("overruns", Json::Num(o.overruns.load(Ordering::Relaxed) as f64)),
                        ("attr_s", Json::Num(secs(&o.attr_ns))),
                        ("rep_s", Json::Num(secs(&o.rep_ns))),
                        ("grad_s", Json::Num(secs(&o.grad_ns))),
                    ])
                })
                .collect()
        };
        Json::obj(vec![
            ("service", self.inner.metrics.registry.snapshot()),
            ("global", obs::registry().snapshot()),
            ("sim_cache", Json::Obj(sim)),
            ("jobs", Json::Arr(jobs)),
            ("simd", crate::util::simd::status_json()),
        ])
    }
}

impl Drop for EmbeddingService {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: Arc<ServiceInner>) {
    loop {
        // Pop the next ready job (or exit on shutdown).
        let id = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = queue.pop() {
                    inner.metrics.queue_depth.set(queue.len() as i64);
                    break id;
                }
                queue = inner.queue_cv.wait(queue).unwrap();
            }
        };
        let Some(entry) = inner.jobs.lock().unwrap().get(&id).cloned() else {
            continue;
        };
        // Claim the task. None ⇒ another worker is driving it right now
        // (stale queue entry) or it already finished — either way, skip.
        let Some(mut task) = entry.task.lock().unwrap().take() else {
            continue;
        };
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_slice(&inner, id, &entry, &mut task)
        }))
        .unwrap_or_else(|_| {
            let msg = "job worker panicked".to_string();
            entry.state.set_phase(JobPhase::Failed(msg.clone()));
            *entry.result.lock().unwrap() = Some(Err(msg));
            entry.done_cv.notify_all();
            if let Some(j) = &inner.journal {
                j.remove(id);
            }
            SliceOutcome::Finished
        });
        match outcome {
            SliceOutcome::Requeue => {
                *entry.task.lock().unwrap() = Some(task);
                inner.enqueue(id, entry.spec.priority);
            }
            SliceOutcome::Park => {
                // The park span stays open (and the stopwatch running)
                // until the first post-resume slice closes them — the
                // span length *is* the park→resume latency.
                task.parked = Some(Stopwatch::start());
                obs::span_begin(obs::Span::Park, id, 0);
                *entry.task.lock().unwrap() = Some(task);
                // A resume/stop that raced with the park may have enqueued
                // the id while we still held the task (that pop was
                // skipped) — re-enqueue so the job is not stranded.
                if !entry.state.pause_requested() || entry.state.stop_requested() {
                    inner.enqueue(id, entry.spec.priority);
                }
            }
            SliceOutcome::Finished => {
                // Task dropped: session scratch and device tensors freed.
            }
        }
    }
}

/// One scheduling slice: prepare if needed, apply control, run a step
/// quantum, publish a live snapshot, journal durable state.
fn run_slice(
    inner: &ServiceInner,
    id: JobId,
    entry: &JobEntry,
    task: &mut JobTask,
) -> SliceOutcome {
    // Close out a pause-park: the time the task sat in the slot is the
    // park→resume latency.
    if let Some(parked) = task.parked.take() {
        inner.metrics.park_resume_ns.record_duration(parked.elapsed());
        obs::span_end(obs::Span::Park, id, 0);
    }
    // Lazily run the similarity stage + session begin on first claim.
    if task.session.is_none() {
        if entry.state.stop_requested() {
            return finalize(inner, id, entry, task, true);
        }
        if entry.state.pause_requested() {
            let total = task.spec.params.iters;
            entry.state.set_phase(JobPhase::Paused { iter: 0, total });
            return SliceOutcome::Park;
        }
        let prepared = {
            let _sim = obs::span(obs::Span::SimLookup, id, 0);
            pipeline::prepare_similarities(
                &task.spec,
                &entry.state,
                Some(&inner.sim_cache),
                &mut task.timings,
            )
            .and_then(|prep| {
                let session = pipeline::begin_session(&task.spec, prep.p, inner.runtime.clone())?;
                Ok((prep.labels, session))
            })
        };
        match prepared {
            Ok((labels, session)) => {
                task.labels = labels;
                // A resumed session starts past iteration 0; align the
                // bookkeeping so wait/status report resumed progress and
                // the journal cadence continues from there.
                task.iters_run = session.iter();
                task.last_journal_iter = session.iter();
                task.session = Some(session);
            }
            Err(e) => return finalize_err(inner, id, entry, format!("{e:#}")),
        }
    }

    // Live re-parameterisation at the step boundary.
    if let Some(update) = entry.state.take_update() {
        let session = task.session.as_mut().expect("session prepared above");
        let mut params = session.params().clone();
        update.apply(&mut params);
        session.set_params(params);
    }

    if entry.state.stop_requested() {
        return finalize(inner, id, entry, task, true);
    }

    // Split the task borrow so the step loop can write the bookkeeping
    // fields while holding the session.
    let (done, auto_stopped, cur_iter, total) = {
        let JobTask {
            spec,
            session,
            auto,
            iters_run,
            last_kl,
            timings,
            last_snapshot,
            last_journal_iter,
            ..
        } = task;
        let session = session.as_mut().expect("session prepared above");
        let total = session.params().iters;

        if entry.state.pause_requested() {
            entry.state.set_phase(JobPhase::Paused { iter: *iters_run, total });
            publish_snapshot(entry, id, session.as_ref(), last_snapshot, true);
            journal_session(inner, id, spec, session.as_ref());
            *last_journal_iter = *iters_run;
            return SliceOutcome::Park;
        }

        // The quantum: up to MAX_QUANTUM_STEPS steps or QUANTUM_MS.
        // (A session may already be done — e.g. an update lowered
        // `iters` below the current iteration — and falls straight
        // through to finalisation.)
        let m = &inner.metrics;
        match spec.priority {
            Priority::Interactive => m.quanta_interactive.inc(),
            Priority::Batch => m.quanta_batch.inc(),
        }
        let quantum_seq = entry.obs.quanta.fetch_add(1, Ordering::Relaxed);
        let _quantum = obs::span(obs::Span::Quantum, id, quantum_seq);
        let sw = Stopwatch::start();
        let mut auto_stopped = false;
        let mut steps = 0usize;
        while !session.is_done() {
            let stepped = {
                let _step = obs::span(obs::Span::EngineStep, id, *iters_run as u64);
                if faultinject::fire(faultinject::ENGINE_STEP_PANIC) {
                    // Escapes run_slice on purpose: the worker's
                    // catch_unwind must contain it to this job.
                    panic!("injected engine step panic (faultinject)");
                }
                session.step()
            };
            match stepped {
                Ok(stats) => {
                    *iters_run = stats.iter + 1;
                    *last_kl = stats.kl_est;
                    if stats.attr_s > 0.0 || stats.rep_s > 0.0 || stats.grad_s > 0.0 {
                        record_phases(m, &entry.obs, &stats);
                    }
                    if auto.should_stop(stats.iter, stats.kl_est) {
                        auto_stopped = true;
                        break;
                    }
                }
                Err(e) => {
                    timings.optimize_s += sw.elapsed_s();
                    m.quantum_ns.record_duration(sw.elapsed());
                    m.quantum_steps.record(steps as u64);
                    entry.obs.steps.fetch_add(steps as u64, Ordering::Relaxed);
                    return finalize_err(inner, id, entry, format!("{e:#}"));
                }
            }
            steps += 1;
            if entry.state.stop_requested() || entry.state.pause_requested() {
                break;
            }
            if steps >= MAX_QUANTUM_STEPS || sw.elapsed_ms() >= QUANTUM_MS {
                break;
            }
        }
        let quantum = sw.elapsed();
        timings.optimize_s += quantum.as_secs_f64();
        m.quantum_ns.record_duration(quantum);
        m.quantum_steps.record(steps as u64);
        entry.obs.steps.fetch_add(steps as u64, Ordering::Relaxed);
        if quantum.as_millis() as u64 >= 2 * QUANTUM_MS {
            m.overruns.inc();
            entry.obs.overruns.fetch_add(1, Ordering::Relaxed);
        }
        // Boundary states (done/stop/pause) always publish so clients
        // see the final positions; mid-run quanta publish immediately
        // when subscribers are streaming and throttle otherwise.
        let at_boundary = session.is_done()
            || auto_stopped
            || entry.state.stop_requested()
            || entry.state.pause_requested();
        publish_snapshot(entry, id, session.as_ref(), last_snapshot, at_boundary);
        // Durable services: journal at the configured iteration cadence
        // (pause journals unconditionally above, finalise removes).
        if *iters_run >= *last_journal_iter + inner.journal_every {
            journal_session(inner, id, spec, session.as_ref());
            *last_journal_iter = *iters_run;
        }
        // Step-boundary rendezvous for `checkpoint` requests.
        serve_checkpoint(entry, session.as_ref());
        (session.is_done(), auto_stopped, *iters_run, total)
    };

    if done || auto_stopped || entry.state.stop_requested() {
        let stopped = (auto_stopped || entry.state.stop_requested()) && !done;
        return finalize(inner, id, entry, task, stopped);
    }
    if entry.state.pause_requested() {
        entry.state.set_phase(JobPhase::Paused { iter: cur_iter, total });
        // Parking always journals: a paused job may sit for days, and a
        // restart must resume it from exactly its parked iteration.
        if let Some(session) = task.session.as_ref() {
            journal_session(inner, id, &task.spec, session.as_ref());
            task.last_journal_iter = cur_iter;
        }
        return SliceOutcome::Park;
    }
    entry.state.set_phase(JobPhase::Optimizing { iter: cur_iter, total });
    SliceOutcome::Requeue
}

/// Serve a pending `checkpoint` rendezvous (see
/// [`EmbeddingService::checkpoint`]): capture the session state at this
/// step boundary and wake the waiting client.
fn serve_checkpoint(entry: &JobEntry, session: &dyn EmbeddingSession) {
    let mut slot = entry.ckpt.lock().unwrap();
    if slot.pending {
        slot.pending = false;
        slot.ready = Some(session.checkpoint());
        entry.ckpt_cv.notify_all();
    }
}

/// Journal one session's durable state: the spec (with the session's
/// *current* params, so live `update`s survive restarts) plus the full
/// checkpoint. No-op without a state dir.
fn journal_session(
    inner: &ServiceInner,
    id: JobId,
    spec: &JobSpec,
    session: &dyn EmbeddingSession,
) {
    let Some(journal) = &inner.journal else {
        return;
    };
    let mut spec = spec.clone();
    spec.params = session.params().clone();
    // The journal record carries the checkpoint out of band; the spec's
    // own initial-state directives are consumed/superseded by it.
    spec.y0 = None;
    spec.resume_from = None;
    let spec_json = super::protocol::spec_to_json(&spec).to_string();
    journal.write(id, &spec_json, &session.checkpoint().to_bytes());
}

/// Publish a live snapshot straight from the session state (no
/// `snapshot_every` gating — the scheduler's quantum is the cadence).
/// The positions copy is the cost, so without an active subscriber the
/// `latest` slot is only refreshed every [`IDLE_SNAPSHOT_MS`]; `force`
/// (boundaries: pause, stop, done) always publishes.
fn publish_snapshot(
    entry: &JobEntry,
    id: JobId,
    session: &dyn EmbeddingSession,
    last: &mut Option<Stopwatch>,
    force: bool,
) {
    let Some(stats) = session.last_stats() else {
        return;
    };
    // The subscriber count is read HERE, at publish time — never cached
    // across the quantum. A client that subscribed while the quantum was
    // stepping must flip this publish to streaming cadence immediately,
    // not after the idle throttle window drains (regression-pinned by
    // `mid_run_subscriber_streams_at_quantum_cadence`).
    let due = force
        || entry.state.snapshots.subscriber_count() > 0
        || last.map_or(true, |t| t.elapsed_ms() >= IDLE_SNAPSHOT_MS);
    if !due {
        return;
    }
    *last = Some(Stopwatch::start());
    let _span = obs::span(obs::Span::SnapshotPublish, id, stats.iter as u64);
    entry.state.publish(Snapshot {
        iter: stats.iter,
        kl_est: stats.kl_est,
        elapsed_s: stats.elapsed_s,
        positions: Arc::new(session.positions().to_vec()),
        published_ns: obs::now_ns(),
    });
}

/// Fold one step's phase breakdown ([`IterStats::attr_s`] and friends,
/// seconds) into the service histograms and the job's accumulators
/// (nanoseconds).
fn record_phases(m: &SchedMetrics, job: &JobObs, stats: &IterStats) {
    let ns = |s: f64| (s.max(0.0) * 1e9) as u64;
    m.attr_ns.record(ns(stats.attr_s));
    m.rep_ns.record(ns(stats.rep_s));
    m.grad_ns.record(ns(stats.grad_s));
    job.attr_ns.fetch_add(ns(stats.attr_s), Ordering::Relaxed);
    job.rep_ns.fetch_add(ns(stats.rep_s), Ordering::Relaxed);
    job.grad_ns.fetch_add(ns(stats.grad_s), Ordering::Relaxed);
}

fn finalize(
    inner: &ServiceInner,
    id: JobId,
    entry: &JobEntry,
    task: &mut JobTask,
    stopped: bool,
) -> SliceOutcome {
    let embedding = task
        .session
        .as_ref()
        .map(|s| s.positions().to_vec())
        .unwrap_or_default();
    if let Some(session) = task.session.as_ref() {
        publish_snapshot(entry, id, session.as_ref(), &mut task.last_snapshot, true);
    }
    let result = JobResult {
        spec: task.spec.clone(),
        embedding,
        labels: std::mem::take(&mut task.labels),
        timings: task.timings.clone(),
        kl_est: task.last_kl,
        iters_run: task.iters_run,
        stopped_early: stopped,
    };
    entry
        .state
        .set_phase(if stopped { JobPhase::Stopped } else { JobPhase::Done });
    *entry.result.lock().unwrap() = Some(Ok(result));
    entry.done_cv.notify_all();
    if let Some(j) = &inner.journal {
        j.remove(id);
    }
    SliceOutcome::Finished
}

fn finalize_err(inner: &ServiceInner, id: JobId, entry: &JobEntry, msg: String) -> SliceOutcome {
    entry.state.set_phase(JobPhase::Failed(msg.clone()));
    *entry.result.lock().unwrap() = Some(Err(msg));
    entry.done_cv.notify_all();
    // A failed job is terminal: re-admitting it on restart would just
    // fail again, so its journal entry goes too.
    if let Some(j) = &inner.journal {
        j.remove(id);
    }
    SliceOutcome::Finished
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::KnnMethod;
    use crate::embed::OptParams;

    fn tiny_spec(iters: usize) -> JobSpec {
        JobSpec {
            dataset: "gaussians".into(),
            n: 100,
            engine: "bh-0.5".into(),
            perplexity: 8.0,
            knn: KnnMethod::Brute,
            params: OptParams { iters, exaggeration_iters: 10, ..Default::default() },
            snapshot_every: 5,
            auto_stop: None,
            priority: Priority::Interactive,
            seed: 1,
            y0: None,
            resume_from: None,
        }
    }

    #[test]
    fn submit_wait_roundtrip() {
        let svc = EmbeddingService::new(None, 2);
        let id = svc.submit(tiny_spec(30));
        let res = svc.wait(id).unwrap();
        assert_eq!(res.embedding.len(), 200);
        assert_eq!(svc.phase(id), Some(JobPhase::Done));
    }

    #[test]
    fn concurrent_jobs_complete() {
        let svc = Arc::new(EmbeddingService::new(None, 2));
        let ids: Vec<_> = (0..4).map(|_| svc.submit(tiny_spec(20))).collect();
        for id in ids {
            let res = svc.wait(id).unwrap();
            assert!(res.embedding.iter().all(|v| v.is_finite()));
        }
        assert_eq!(svc.list().len(), 4);
    }

    #[test]
    fn more_jobs_than_workers_interleave_not_starve() {
        // One worker, three long jobs: round-robin quanta mean every job
        // must make progress long before any of them completes.
        let svc = EmbeddingService::new(None, 1);
        let ids: Vec<_> = (0..3).map(|_| svc.submit(tiny_spec(100_000))).collect();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            let progressed = ids
                .iter()
                .filter(|&&id| {
                    matches!(svc.phase(id), Some(JobPhase::Optimizing { iter, .. }) if iter > 0)
                        || svc.latest_snapshot(id).is_some()
                })
                .count();
            if progressed == ids.len() {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "jobs failed to interleave: phases {:?}",
                svc.list()
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        for &id in &ids {
            assert!(svc.stop(id));
        }
        for &id in &ids {
            let res = svc.wait(id).unwrap();
            assert!(res.stopped_early);
        }
    }

    #[test]
    fn stop_mid_flight() {
        let svc = EmbeddingService::new(None, 1);
        let id = svc.submit(tiny_spec(5000));
        let rx = svc.subscribe(id).unwrap();
        let _ = rx.recv(); // first snapshot = job is running
        assert!(svc.stop(id));
        let res = svc.wait(id).unwrap();
        assert!(res.stopped_early);
        assert_eq!(svc.phase(id), Some(JobPhase::Stopped));
    }

    #[test]
    fn pause_parks_and_resume_finishes() {
        let svc = EmbeddingService::new(None, 1);
        let id = svc.submit(tiny_spec(100_000));
        let rx = svc.subscribe(id).unwrap();
        let first = rx.recv().expect("job is stepping");
        assert!(svc.pause(id));
        // Wait until the scheduler actually parks it.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        let paused_iter = loop {
            if let Some(JobPhase::Paused { iter, .. }) = svc.phase(id) {
                break iter;
            }
            assert!(std::time::Instant::now() < deadline, "never parked");
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        assert!(paused_iter >= first.iter, "pause can only move forward");
        // Parked: no further progress.
        std::thread::sleep(std::time::Duration::from_millis(80));
        assert!(
            matches!(svc.phase(id), Some(JobPhase::Paused { iter, .. }) if iter == paused_iter),
            "paused job must not advance"
        );
        // Cut the job short at the next boundary, then resume.
        assert!(svc.update(
            id,
            ParamUpdate { iters: Some(paused_iter.max(1)), ..Default::default() }
        ));
        assert!(svc.resume(id));
        let res = svc.wait(id).unwrap();
        assert!(!res.stopped_early, "shortened via update, not stopped");
        assert!(res.iters_run <= paused_iter.max(1) + MAX_QUANTUM_STEPS);
        assert_eq!(svc.phase(id), Some(JobPhase::Done));
        assert!(
            svc.inner.metrics.park_resume_ns.count() >= 1,
            "the park→resume latency must be recorded at the first post-resume slice"
        );
    }

    #[test]
    fn stop_finalises_a_paused_job() {
        let svc = EmbeddingService::new(None, 1);
        let id = svc.submit(tiny_spec(100_000));
        let rx = svc.subscribe(id).unwrap();
        let _ = rx.recv();
        assert!(svc.pause(id));
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while !matches!(svc.phase(id), Some(JobPhase::Paused { .. })) {
            assert!(std::time::Instant::now() < deadline, "never parked");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(svc.stop(id));
        let res = svc.wait(id).unwrap();
        assert!(res.stopped_early);
        assert_eq!(svc.phase(id), Some(JobPhase::Stopped));
    }

    #[test]
    fn repeated_jobs_hit_the_similarity_cache() {
        let svc = EmbeddingService::new(None, 2);
        let a = svc.submit(tiny_spec(20));
        let ra = svc.wait(a).unwrap();
        assert!(!ra.timings.sim_cache_hit);
        let b = svc.submit(tiny_spec(20));
        let rb = svc.wait(b).unwrap();
        assert!(rb.timings.sim_cache_hit, "identical resubmission must hit");
        assert_eq!(ra.embedding, rb.embedding);
        assert_eq!(svc.sim_cache().stats(), (1, 1));
        assert_eq!(svc.sim_cache().len(), 1);
    }

    #[test]
    fn concurrent_identical_submits_run_one_knn() {
        // Two identical jobs racing through two workers: whether they
        // overlap in the similarity stage (coalesced wait) or not (plain
        // ready hit), exactly one kNN+P computation may run.
        let svc = EmbeddingService::new(None, 2);
        let a = svc.submit(tiny_spec(15));
        let b = svc.submit(tiny_spec(15));
        let ra = svc.wait(a).unwrap();
        let rb = svc.wait(b).unwrap();
        assert_eq!(svc.sim_cache().computes(), 1, "second submit must reuse the first's work");
        assert_eq!(svc.sim_cache().stats(), (1, 1));
        assert!(ra.timings.sim_cache_hit != rb.timings.sim_cache_hit, "one leader, one hit");
        assert_eq!(ra.embedding, rb.embedding);
    }

    #[test]
    fn failed_job_reports_phase() {
        let svc = EmbeddingService::new(None, 1);
        let mut spec = tiny_spec(5);
        spec.dataset = "no-such-dataset".into();
        let id = svc.submit(spec);
        assert!(svc.wait(id).is_err());
        assert!(matches!(svc.phase(id), Some(JobPhase::Failed(_))));
    }

    #[test]
    fn unknown_job_is_none() {
        let svc = EmbeddingService::new(None, 1);
        assert!(svc.phase(999).is_none());
        assert!(!svc.stop(999));
        assert!(!svc.pause(999));
        assert!(!svc.resume(999));
        assert!(!svc.update(999, ParamUpdate::default()));
        assert!(svc.checkpoint(999).is_err());
    }

    #[test]
    fn checkpoint_command_snapshots_live_state() {
        let svc = EmbeddingService::new(None, 1);
        let id = svc.submit(tiny_spec(100_000));
        let rx = svc.subscribe(id).unwrap();
        let _ = rx.recv().expect("job is stepping");
        let ck = svc.checkpoint(id).expect("live checkpoint");
        assert!(ck.iter > 0, "captured mid-run");
        assert_eq!(ck.y.len(), 200);
        // The blob round-trips through the byte codec (what the TCP
        // layer frames in base64).
        let back = crate::embed::Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back, ck);
        // Resubmitting the checkpoint resumes from its iteration.
        let mut resumed_spec = tiny_spec(ck.iter + 3);
        resumed_spec.resume_from = Some(ck.to_bytes());
        let rid = svc.submit(resumed_spec);
        let res = svc.wait(rid).unwrap();
        assert_eq!(res.iters_run, ck.iter + 3, "resumed past the checkpoint iteration");
        assert!(svc.stop(id));
        let _ = svc.wait(id);
        // Terminal jobs no longer expose a live checkpoint.
        assert!(svc.checkpoint(id).is_err());
    }

    #[test]
    fn mid_run_subscriber_streams_at_quantum_cadence() {
        // Regression: without a subscriber the `latest` snapshot is
        // throttled to IDLE_SNAPSHOT_MS. A subscriber that attaches
        // mid-run (mid-quantum included) must immediately flip
        // publishing to streaming cadence — the subscriber count has to
        // be re-read at publish time, not captured when the quantum
        // started. Throttled cadence at this problem size would space
        // snapshots thousands of iterations apart; streaming cadence is
        // one publish per quantum (≤ MAX_QUANTUM_STEPS steps).
        let svc = EmbeddingService::new(None, 1);
        let id = svc.submit(tiny_spec(1_000_000));
        // Let the job run throttled for a while first.
        while svc.latest_snapshot(id).is_none() {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        std::thread::sleep(std::time::Duration::from_millis(30));
        let rx = svc.subscribe(id).unwrap();
        let mut iters = Vec::new();
        while iters.len() < 5 {
            let s = rx
                .recv_timeout(std::time::Duration::from_secs(10))
                .expect("subscriber must start receiving promptly");
            iters.push(s.iter);
        }
        for w in iters.windows(2) {
            assert!(
                w[1] - w[0] <= 2 * MAX_QUANTUM_STEPS,
                "snapshots {} -> {} spaced like the idle throttle, not the quantum cadence",
                w[0],
                w[1]
            );
        }
        assert!(svc.stop(id));
        let _ = svc.wait(id);
    }

    #[test]
    fn scheduler_metrics_expose_fair_quanta() {
        // One worker, one huge *batch* job racing three small
        // *interactive* ones: the weighted round-robin means the small
        // jobs complete while the big one keeps taking its (reduced)
        // share of slices — and the scheduler metrics must show both the
        // fairness and the class weighting.
        let svc = EmbeddingService::new(None, 1);
        let mut big_spec = tiny_spec(1_000_000);
        big_spec.priority = Priority::Batch;
        let big = svc.submit(big_spec);
        let smalls: Vec<_> = (0..3).map(|_| svc.submit(tiny_spec(400))).collect();
        for &id in &smalls {
            svc.wait(id).unwrap();
        }
        // Captured before stopping the big job: once the interactive
        // jobs are done the batch class owns every pop, so the
        // contention-window ratio is only visible now.
        let contended_interactive = svc.inner.metrics.quanta_interactive.get();
        let contended_batch = svc.inner.metrics.quanta_batch.get();
        let quanta_of = |id: JobId| svc.entry(id).unwrap().obs.quanta.load(Ordering::Relaxed);
        // A 400-iteration job runs at most MAX_QUANTUM_STEPS steps per
        // quantum, so finishing took each small job several quanta...
        for &id in &smalls {
            assert!(
                quanta_of(id) >= (400 / MAX_QUANTUM_STEPS) as u64,
                "job {id} finished in implausibly few quanta: {}",
                quanta_of(id)
            );
        }
        // ...and the big job kept getting slices throughout — the
        // anti-starvation guarantee for batch, now observable instead of
        // inferred.
        assert!(quanta_of(big) >= 2, "big job starved: {} quanta", quanta_of(big));
        // The weighting held while both classes were contending: the
        // interactive class took quanta ahead of batch (3:1 nominal;
        // ≥ is the race-proof bound), and batch was never starved.
        assert!(
            contended_interactive >= contended_batch,
            "interactive ({contended_interactive}) must lead batch ({contended_batch})"
        );
        assert!(contended_batch >= 1, "batch class starved under contention");
        assert!(svc.stop(big));
        svc.wait(big).unwrap();
        // Every quantum of every job landed in the service histograms.
        let m = &svc.inner.metrics;
        let total: u64 = std::iter::once(big).chain(smalls.iter().copied()).map(quanta_of).sum();
        assert_eq!(m.quantum_ns.count(), total);
        assert_eq!(m.quantum_steps.count(), total);
        // Every quantum was attributed to exactly one scheduling class.
        assert_eq!(m.quanta_interactive.get() + m.quanta_batch.get(), total);
        // Sub-millisecond steps cannot legitimately blow a 2× budget;
        // the slack is for CI scheduling hiccups.
        assert!(
            m.overruns.get() <= total / 2,
            "implausible overrun count: {}/{total}",
            m.overruns.get()
        );
        // The merged `metrics` snapshot carries the same numbers.
        let mj = svc.metrics_json();
        let hist = mj.get("service").unwrap().get("histograms").unwrap();
        assert_eq!(
            hist.get("scheduler.quantum_ns").unwrap().num_field("count"),
            Some(total as f64)
        );
        let Some(Json::Arr(jobs)) = mj.get("jobs") else {
            panic!("metrics_json jobs section missing");
        };
        assert_eq!(jobs.len(), 4);
        assert!(jobs.iter().all(|j| j.num_field("quanta").unwrap() >= 1.0));
        assert!(jobs.iter().all(|j| j.num_field("steps").unwrap() >= 1.0));
    }

    #[test]
    fn ready_queue_interleaves_exactly_three_to_one_and_drains_lanes_fifo() {
        // Direct simulation of the scheduler's queue discipline: two
        // jobs per class, each re-pushed after its pop (a saturated
        // worker's steady state). The interleave is deterministic: the
        // pop counter sends every (BATCH_POP_PERIOD)th pop to batch.
        let mut q = ReadyQueue::default();
        q.push(1, Priority::Interactive);
        q.push(2, Priority::Interactive);
        q.push(101, Priority::Batch);
        q.push(102, Priority::Batch);
        let mut got = Vec::new();
        for _ in 0..400 {
            let id = q.pop().expect("both lanes populated");
            got.push(id);
            q.push(id, if id < 100 { Priority::Interactive } else { Priority::Batch });
        }
        for (i, &id) in got.iter().enumerate() {
            assert_eq!(
                id >= 100,
                i % BATCH_POP_PERIOD as usize == BATCH_POP_PERIOD as usize - 1,
                "pop {i} went to job {id}: the 3:1 pattern must be exact under saturation"
            );
        }
        assert_eq!(got.iter().filter(|&&id| id >= 100).count(), 100, "100 of 400 pops are batch");
        // FIFO within each class: consecutive picks of a class alternate.
        assert_eq!(&got[..8], &[1, 2, 1, 101, 2, 1, 2, 102][..]);

        // Lane-drain edge: once a class empties, the other drains
        // back-to-back — the weighting never reserves an idle slot.
        let mut q = ReadyQueue::default();
        q.push(1, Priority::Interactive);
        q.push(101, Priority::Batch);
        q.push(102, Priority::Batch);
        assert_eq!(q.pop(), Some(1), "first pop is interactive");
        assert_eq!(q.pop(), Some(101), "empty interactive lane yields to batch immediately");
        assert_eq!(q.pop(), Some(102));
        assert_eq!(q.pop(), None);
        assert_eq!(q.len(), 0);
        // And symmetrically with batch empty: interactive never skips.
        q.push(1, Priority::Interactive);
        q.push(2, Priority::Interactive);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn saturated_worker_splits_quanta_three_to_one_until_a_lane_empties() {
        // Service-level pin of the same contract, observed through the
        // scheduler counters the `metrics` command exports: one worker,
        // two effectively-endless jobs per class, so both lanes stay
        // populated at every pop and the 3:1 weighting is exact up to
        // window-alignment noise.
        let svc = EmbeddingService::new(None, 1);
        let mut batch_spec = tiny_spec(1_000_000);
        batch_spec.priority = Priority::Batch;
        let batch: Vec<_> = (0..2).map(|_| svc.submit(batch_spec.clone())).collect();
        let inter: Vec<_> = (0..2).map(|_| svc.submit(tiny_spec(1_000_000))).collect();
        let m = &svc.inner.metrics;
        let (qi0, qb0) = (m.quanta_interactive.get(), m.quanta_batch.get());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
        let window = loop {
            let (di, db) = (m.quanta_interactive.get() - qi0, m.quanta_batch.get() - qb0);
            if di + db >= 240 {
                break (di, db);
            }
            assert!(
                std::time::Instant::now() < deadline,
                "scheduler stalled at {di}+{db} quanta"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        let (di, db) = window;
        assert!(db >= 1, "batch lane starved under contention");
        assert!(di >= 1, "interactive lane starved under contention");
        let skew = di as f64 / db as f64;
        assert!(
            (2.2..=3.8).contains(&skew),
            "contended skew {skew:.2} ({di}:{db}) strayed from the nominal 3:1"
        );

        // Starvation edge: empty the interactive lane and the batch
        // class must own every subsequent quantum — the frozen
        // interactive counter is the proof there's no phantom slot.
        for &id in &inter {
            assert!(svc.stop(id));
        }
        for &id in &inter {
            assert!(svc.wait(id).unwrap().stopped_early);
        }
        let qi_frozen = m.quanta_interactive.get();
        let qb_mark = m.quanta_batch.get();
        while m.quanta_batch.get() < qb_mark + 16 {
            assert!(
                std::time::Instant::now() < deadline,
                "batch made no progress after the interactive lane emptied"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(
            m.quanta_interactive.get(),
            qi_frozen,
            "interactive quanta advanced while its lane was empty"
        );
        for &id in &batch {
            assert!(svc.stop(id));
        }
        for &id in &batch {
            assert!(svc.wait(id).unwrap().stopped_early);
        }
    }

    #[test]
    fn admission_control_sheds_over_the_queue_cap() {
        let cfg = ServiceConfig { max_concurrent: 1, max_queue_depth: 1, ..Default::default() };
        let svc = EmbeddingService::with_config(None, cfg);
        // Three long jobs on one worker: at most one is ever claimed, so
        // the ready queue holds at least two — permanently over the cap.
        let ids: Vec<_> = (0..3).map(|_| svc.submit(tiny_spec(100_000))).collect();
        match svc.try_submit(tiny_spec(10)) {
            Err(SubmitError::QueueFull { depth, cap }) => {
                assert_eq!(cap, 1);
                assert!(depth >= 1);
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert!(svc.inner.metrics.submits_shed.get() >= 1);
        for &id in &ids {
            assert!(svc.stop(id));
        }
        for &id in &ids {
            let _ = svc.wait(id);
        }
    }

    #[test]
    fn drain_parks_and_journals_live_jobs() {
        let dir = std::env::temp_dir().join(format!("gsne-svc-drain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = || ServiceConfig {
            max_concurrent: 2,
            state_dir: Some(dir.clone()),
            // Huge cadence: only a pause/park (or drain) can journal a
            // checkpoint, so the assertion below pins drain's journal.
            journal_every: 1_000_000,
            ..Default::default()
        };
        let (id, parked_iter) = {
            let svc = EmbeddingService::with_config(None, cfg());
            let id = svc.submit(tiny_spec(1_000_000));
            let rx = svc.subscribe(id).unwrap();
            let _ = rx.recv().expect("job is stepping");
            let live = svc.drain(std::time::Duration::from_secs(30));
            assert_eq!(live, 1, "one live session drained");
            assert!(svc.is_draining());
            let Some(JobPhase::Paused { iter, .. }) = svc.phase(id) else {
                panic!("drained job must be parked, got {:?}", svc.phase(id));
            };
            assert!(iter > 0, "drained mid-run");
            // Draining admits nothing new.
            assert_eq!(svc.try_submit(tiny_spec(10)), Err(SubmitError::Draining));
            // The park journalled a real checkpoint (not just the
            // admission-time spec record).
            let entries = svc.inner.journal.as_ref().unwrap().read_all();
            assert_eq!(entries.len(), 1);
            assert!(!entries[0].checkpoint.is_empty(), "drain must journal session state");
            (id, iter)
        };
        // Restart: the drained job resumes from its parked iteration.
        let svc = EmbeddingService::with_config(None, cfg());
        assert!(svc.phase(id).is_some_and(|p| !p.is_terminal()), "re-admitted");
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while !matches!(svc.phase(id), Some(JobPhase::Optimizing { .. })) {
            assert!(std::time::Instant::now() < deadline, "resumed job never ran");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(svc.update(
            id,
            ParamUpdate { iters: Some(parked_iter + 100), ..Default::default() }
        ));
        let res = svc.wait(id).unwrap();
        assert!(
            res.iters_run >= parked_iter,
            "resumed from the drained checkpoint: {} vs {parked_iter}",
            res.iters_run
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_service_journals_and_readmits_jobs() {
        let dir = std::env::temp_dir()
            .join(format!("gsne-svc-journal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = || ServiceConfig {
            max_concurrent: 1,
            state_dir: Some(dir.clone()),
            journal_every: 5,
            ..Default::default()
        };
        let (id, journalled_iter) = {
            let svc = EmbeddingService::with_config(None, cfg());
            assert!(svc.is_durable());
            let id = svc.submit(tiny_spec(1_000_000));
            // Wait until a journal record exists.
            let path = dir.join("jobs").join(format!("job-{id}.job"));
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
            while !path.exists() {
                assert!(std::time::Instant::now() < deadline, "journal never written");
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            // Drop the service mid-run: the journal entry must survive.
            let iter = svc.latest_snapshot(id).map(|s| s.iter).unwrap_or(0);
            (id, iter)
        };
        // "Restart": a new service over the same state dir re-admits it
        // (the workers may already be driving it by the time we look).
        let svc = EmbeddingService::with_config(None, cfg());
        let phase = svc.phase(id).expect("re-admitted under the same id");
        assert!(!phase.is_terminal(), "re-admitted job is runnable: {phase:?}");
        // Cap the horizon so the resumed job finishes quickly.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while !matches!(svc.phase(id), Some(JobPhase::Optimizing { .. })) {
            assert!(std::time::Instant::now() < deadline, "resumed job never ran");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(svc.update(
            id,
            ParamUpdate { iters: Some(journalled_iter + 500), ..Default::default() }
        ));
        let res = svc.wait(id).unwrap();
        assert!(
            res.iters_run >= journalled_iter.saturating_sub(2 * MAX_QUANTUM_STEPS),
            "resumed near the journalled iteration, not from zero: {} vs {journalled_iter}",
            res.iters_run
        );
        // Fresh submits continue above the re-admitted id.
        let id2 = svc.submit(tiny_spec(5));
        assert!(id2 > id);
        let _ = svc.wait(id2);
        // Finished jobs clear their journal entries.
        assert!(
            svc.inner.journal.as_ref().unwrap().read_all().is_empty(),
            "journal drained after completion"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
