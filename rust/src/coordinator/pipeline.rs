//! The per-job pipeline: dataset → kNN → perplexity/P → optimise, with
//! stage timings, progressive snapshots, auto-stop and user stop.
//!
//! The pipeline is split at the session boundary so the service's
//! cooperative scheduler can drive the optimise stage in step quanta:
//!
//! * [`prepare_similarities`] — dataset load + kNN + P build, optionally
//!   served from (and coalesced through) a
//!   [`super::simcache::SimilarityCache`]: a cache hit replaces both
//!   stages with a dataset fingerprint, and concurrent identical
//!   submissions block on the first computation instead of re-running it.
//! * [`begin_session`] — construct the engine and open its
//!   [`EmbeddingSession`].
//! * [`run_pipeline`] / [`run_pipeline_cached`] — the synchronous
//!   convenience used by the CLI, examples and tests: prepare, begin,
//!   then loop the session to completion inline (honouring stop
//!   requests, pending parameter updates and auto-stop).

use std::sync::Arc;

use crate::data;
use crate::embed::{self, EmbeddingSession};
use crate::hd::{backend, perplexity, Dataset, KnnGraph, SparseP};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::timer::fmt_secs;

use super::job::{AutoStop, JobPhase, JobSpec, KnnMethod, Snapshot};
use super::progress::JobState;
use super::simcache::{GraphKey, SimKey, SimilarityCache};

/// Wall time per pipeline stage (seconds) — the breakdown the paper's
/// timing rows decompose into (similarities vs minimisation).
#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    pub dataset_s: f64,
    pub knn_s: f64,
    pub perplexity_s: f64,
    pub optimize_s: f64,
    /// The similarity stage (kNN + perplexity/P) was served from the
    /// coordinator store — a ready in-memory entry, a coalesced wait on
    /// a concurrent identical computation, or an on-disk record;
    /// `knn_s` then measures only the fingerprint + lookup (or wait)
    /// and `perplexity_s` is 0.
    pub sim_cache_hit: bool,
    /// The P matrix had to be (re)built, but its kNN *graph* was served
    /// from the store (level 1) — the perplexity-sweep fast path: only
    /// the cheap fused P build ran.
    pub knn_cache_hit: bool,
}

impl StageTimings {
    pub fn total(&self) -> f64 {
        self.dataset_s + self.knn_s + self.perplexity_s + self.optimize_s
    }

    /// The paper's "similarities" row: kNN + perplexity/P.
    pub fn similarities_s(&self) -> f64 {
        self.knn_s + self.perplexity_s
    }

    /// The one serialisation of a timing breakdown: every surface that
    /// reports stage timings (the CLI's end-of-run line, the protocol's
    /// `wait` and `status` responses) goes through this, so a new stage
    /// field cannot silently drift out of one of them.
    pub fn to_json_fields(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("dataset_s", Json::Num(self.dataset_s)),
            ("knn_s", Json::Num(self.knn_s)),
            ("perplexity_s", Json::Num(self.perplexity_s)),
            ("optimize_s", Json::Num(self.optimize_s)),
            ("similarities_s", Json::Num(self.similarities_s())),
            ("total_s", Json::Num(self.total())),
            ("sim_cache_hit", Json::Bool(self.sim_cache_hit)),
            ("knn_cache_hit", Json::Bool(self.knn_cache_hit)),
        ]
    }

    /// Human rendering of [`Self::to_json_fields`] for the CLI: seconds
    /// fields formatted with [`fmt_secs`], cache-hit booleans appended
    /// as annotations.
    pub fn human_summary(&self) -> String {
        let mut parts = Vec::new();
        let mut notes = Vec::new();
        for (name, v) in self.to_json_fields() {
            match v {
                Json::Num(s) => {
                    parts.push(format!("{} {}", name.trim_end_matches("_s"), fmt_secs(s)))
                }
                Json::Bool(true) => notes.push(name.trim_end_matches("_hit").replace('_', " ")),
                _ => {}
            }
        }
        let notes =
            if notes.is_empty() { String::new() } else { format!(" ({} hit)", notes.join(", ")) };
        format!("{}{notes}", parts.join(" | "))
    }
}

/// Final product of a job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub spec: JobSpec,
    /// `(n, 2)` row-major final embedding.
    pub embedding: Vec<f32>,
    pub labels: Vec<u8>,
    pub timings: StageTimings,
    /// Last per-iteration KL estimate observed.
    pub kl_est: f64,
    pub iters_run: usize,
    pub stopped_early: bool,
}

/// Compute the kNN graph by the requested method (dispatched through the
/// `hd::backend` registry — `KnnMethod` names are registry names).
pub fn compute_knn(data: &Dataset, method: KnnMethod, k: usize, seed: u64) -> KnnGraph {
    backend::by_name(method.backend_name())
        .expect("KnnMethod names are registry names")
        .knn(data, k, seed)
}

/// Everything the optimise stage needs, produced by
/// [`prepare_similarities`].
pub struct PreparedJob {
    pub p: Arc<SparseP>,
    pub labels: Vec<u8>,
}

/// Dataset load + similarity stage (kNN + perplexity/P), optionally
/// through the coalescing cache. Fills `dataset_s`/`knn_s`/
/// `perplexity_s`/`sim_cache_hit` and advances the job phase.
pub fn prepare_similarities(
    spec: &JobSpec,
    state: &JobState,
    cache: Option<&SimilarityCache>,
    timings: &mut StageTimings,
) -> anyhow::Result<PreparedJob> {
    let t = std::time::Instant::now();
    let dataset = data::by_name(&spec.dataset, spec.n, spec.seed)?;
    timings.dataset_s = t.elapsed().as_secs_f64();

    state.set_phase(JobPhase::Knn);
    let t = std::time::Instant::now();
    let k = spec.knn_k().min(dataset.n.saturating_sub(1)).max(1);
    let perp = spec.perplexity.min(k as f32);
    let p = match cache {
        Some(cache) => {
            let key = SimKey {
                graph: GraphKey {
                    fingerprint: dataset.fingerprint(),
                    method: spec.knn,
                    k,
                    // Seed-insensitive backends (brute) key seed-blind
                    // so seed sweeps over identical data share an entry.
                    seed: if spec.knn.seed_sensitive() { spec.seed } else { 0 },
                },
                perplexity_bits: perp.to_bits(),
            };
            let lookup = cache.get_or_compute(
                &key,
                || Ok(Arc::new(compute_knn(&dataset, spec.knn, k, spec.seed))),
                |knn| {
                    state.set_phase(JobPhase::Perplexity);
                    Ok(Arc::new(perplexity::joint_p(knn, perp)))
                },
            )?;
            if lookup.p_source.is_hit() {
                // Ready entry, coalesced onto a concurrent leader, or an
                // on-disk record: knn_s is the fingerprint/lookup/wait,
                // no P build ran.
                timings.sim_cache_hit = true;
                timings.knn_s = t.elapsed().as_secs_f64();
                timings.perplexity_s = 0.0;
            } else {
                // P was built; the graph may still have been served
                // (level-1 hit — the perplexity-sweep fast path). In
                // that case knn_s is the graph lookup/wait alone: the
                // total elapsed minus the P build that also ran inside
                // get_or_compute (charging the full elapsed would
                // double-count the build in similarities_s()).
                timings.knn_cache_hit =
                    lookup.graph_source.map(|s| s.is_hit()).unwrap_or(false);
                timings.knn_s = if timings.knn_cache_hit {
                    (t.elapsed().as_secs_f64() - lookup.perplexity_s).max(0.0)
                } else {
                    lookup.knn_s
                };
                timings.perplexity_s = lookup.perplexity_s;
            }
            lookup.p
        }
        None => {
            let knn_t = std::time::Instant::now();
            let knn = compute_knn(&dataset, spec.knn, k, spec.seed);
            timings.knn_s = knn_t.elapsed().as_secs_f64();
            state.set_phase(JobPhase::Perplexity);
            let p_t = std::time::Instant::now();
            let p = Arc::new(perplexity::joint_p(&knn, perp));
            timings.perplexity_s = p_t.elapsed().as_secs_f64();
            p
        }
    };
    Ok(PreparedJob { p, labels: dataset.labels })
}

/// Construct the engine named by the spec and open its session, then
/// apply the spec's initial-state directives: `y0` warm-starts the
/// session from a client-supplied layout, and `resume_from` restores a
/// serialised [`crate::embed::Checkpoint`] (the durable-job path — the
/// session continues from the checkpointed iteration as if it had never
/// stopped). When both are present the checkpoint wins: it is applied
/// last and carries the full optimiser state.
pub fn begin_session(
    spec: &JobSpec,
    p: Arc<SparseP>,
    runtime: Option<Arc<Runtime>>,
) -> anyhow::Result<Box<dyn EmbeddingSession>> {
    let mut session = embed::by_name(&spec.engine, runtime)?.begin(p, &spec.params)?;
    if let Some(y0) = &spec.y0 {
        session.warm_start(y0)?;
    }
    if let Some(bytes) = &spec.resume_from {
        let ck = crate::embed::Checkpoint::from_bytes(bytes)?;
        session.restore(&ck)?;
    }
    Ok(session)
}

/// Plateau detector for automatic early termination: stop once the KL
/// estimate improved less than `rel_eps` over the last `window`
/// iterations (only armed after the exaggeration phase). Used by both
/// the synchronous drive loop and the service scheduler.
pub struct AutoStopTracker {
    cfg: Option<AutoStop>,
    armed_after: usize,
    kl_window: Vec<f64>,
}

impl AutoStopTracker {
    pub fn new(cfg: Option<AutoStop>, exaggeration_iters: usize) -> Self {
        Self { cfg, armed_after: exaggeration_iters, kl_window: Vec::new() }
    }

    /// Observe one iteration's KL estimate; true means "plateaued, stop".
    pub fn should_stop(&mut self, iter: usize, kl_est: f64) -> bool {
        let Some(auto) = self.cfg else {
            return false;
        };
        if iter < self.armed_after {
            return false;
        }
        self.kl_window.push(kl_est);
        if self.kl_window.len() > auto.window {
            let old = self.kl_window[self.kl_window.len() - 1 - auto.window];
            let rel = (old - kl_est) / old.abs().max(1e-12);
            return rel < auto.rel_eps;
        }
        false
    }
}

/// Run a full job synchronously. `state` carries phase/stop/snapshots;
/// pass a fresh `JobState` when running outside the service.
pub fn run_pipeline(
    spec: &JobSpec,
    runtime: Option<Arc<Runtime>>,
    state: &JobState,
) -> anyhow::Result<JobResult> {
    run_pipeline_cached(spec, runtime, state, None)
}

/// [`run_pipeline`] with an optional similarity cache (the service passes
/// its own): on a hit the kNN + perplexity stages are skipped entirely.
pub fn run_pipeline_cached(
    spec: &JobSpec,
    runtime: Option<Arc<Runtime>>,
    state: &JobState,
    cache: Option<&SimilarityCache>,
) -> anyhow::Result<JobResult> {
    let mut timings = StageTimings::default();
    let prepared = prepare_similarities(spec, state, cache, &mut timings)?;
    let (embedding, kl_est, iters_run, stopped) =
        optimize(spec, prepared.p, runtime, state, &mut timings)?;
    state.set_phase(if stopped { JobPhase::Stopped } else { JobPhase::Done });
    Ok(JobResult {
        spec: spec.clone(),
        embedding,
        labels: prepared.labels,
        timings,
        kl_est,
        iters_run,
        stopped_early: stopped,
    })
}

/// The synchronous optimise stage: open a session and step it to
/// completion inline (public for benches that precompute P once and
/// sweep engines). Emits snapshots at the spec's `snapshot_every`
/// cadence plus the final iteration, honours stop requests, pending
/// parameter updates and auto-stop. Pause requests are a scheduler
/// feature and are ignored here — the synchronous caller *is* the
/// driver.
pub fn optimize(
    spec: &JobSpec,
    p: Arc<SparseP>,
    runtime: Option<Arc<Runtime>>,
    state: &JobState,
    timings: &mut StageTimings,
) -> anyhow::Result<(Vec<f32>, f64, usize, bool)> {
    let mut session = begin_session(spec, p, runtime)?;
    let t = std::time::Instant::now();
    let mut auto = AutoStopTracker::new(spec.auto_stop, spec.params.exaggeration_iters);
    let mut last_kl = f64::NAN;
    let mut iters_run = 0usize;
    let mut stopped = false;
    while !session.is_done() {
        if let Some(update) = state.take_update() {
            let mut params = session.params().clone();
            update.apply(&mut params);
            session.set_params(params);
        }
        let stats = session.step()?;
        iters_run = stats.iter + 1;
        last_kl = stats.kl_est;
        let total = session.params().iters;
        state.set_phase(JobPhase::Optimizing { iter: stats.iter + 1, total });
        let emit = spec.snapshot_every > 0 && (stats.iter % spec.snapshot_every == 0);
        if emit || session.is_done() {
            state.publish(Snapshot {
                iter: stats.iter,
                kl_est: stats.kl_est,
                elapsed_s: stats.elapsed_s,
                positions: Arc::new(session.positions().to_vec()),
                published_ns: crate::obs::now_ns(),
            });
        }
        if state.stop_requested() {
            stopped = true;
            break;
        }
        if auto.should_stop(stats.iter, stats.kl_est) {
            stopped = true;
            break;
        }
    }
    timings.optimize_s = t.elapsed().as_secs_f64();
    Ok((session.positions().to_vec(), last_kl, iters_run, stopped))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{AutoStop, ParamUpdate};
    use crate::embed::OptParams;

    fn quick_spec(engine: &str, iters: usize) -> JobSpec {
        JobSpec {
            dataset: "gaussians".into(),
            n: 150,
            engine: engine.into(),
            perplexity: 10.0,
            knn: KnnMethod::Brute,
            params: OptParams { iters, exaggeration_iters: 20, ..Default::default() },
            snapshot_every: 10,
            auto_stop: None,
            priority: Default::default(),
            seed: 3,
            y0: None,
            resume_from: None,
        }
    }

    #[test]
    fn stage_timings_serialise_through_one_helper() {
        let t = StageTimings {
            dataset_s: 0.5,
            knn_s: 1.0,
            perplexity_s: 0.25,
            optimize_s: 2.0,
            sim_cache_hit: false,
            knn_cache_hit: true,
        };
        let j = Json::obj(t.to_json_fields());
        assert_eq!(j.num_field("total_s"), Some(3.75));
        assert_eq!(j.num_field("similarities_s"), Some(1.25));
        assert_eq!(j.get("sim_cache_hit"), Some(&Json::Bool(false)));
        assert_eq!(j.get("knn_cache_hit"), Some(&Json::Bool(true)));
        let s = t.human_summary();
        assert!(s.contains("optimize 2.00s"), "{s}");
        assert!(s.ends_with("(knn cache hit)"), "{s}");
    }

    #[test]
    fn pipeline_runs_end_to_end_cpu() {
        let state = JobState::default();
        let rx = state.snapshots.subscribe();
        let res = run_pipeline(&quick_spec("bh-0.5", 60), None, &state).unwrap();
        assert_eq!(res.embedding.len(), 2 * 150);
        assert!(res.embedding.iter().all(|v| v.is_finite()));
        assert_eq!(res.iters_run, 60);
        assert!(!res.stopped_early);
        assert_eq!(state.phase(), JobPhase::Done);
        assert!(res.timings.total() > 0.0);
        // Snapshots flowed (iters 0,10,...,50 and the final).
        let got: Vec<_> = rx.try_iter().collect();
        assert!(got.len() >= 6, "got {} snapshots", got.len());
        assert_eq!(got.last().unwrap().iter, 59);
    }

    #[test]
    fn stop_request_halts_early() {
        let state = JobState::default();
        let rx = state.snapshots.subscribe();
        let spec = quick_spec("bh-0.5", 500);
        // Stop after the first snapshot arrives (from another thread).
        let state2 = state.clone();
        let h = std::thread::spawn(move || {
            let _ = rx.recv();
            state2.request_stop();
        });
        let res = run_pipeline(&spec, None, &state).unwrap();
        h.join().unwrap();
        assert!(res.stopped_early);
        assert!(res.iters_run < 500);
        assert_eq!(state.phase(), JobPhase::Stopped);
    }

    #[test]
    fn auto_stop_triggers_on_plateau() {
        let state = JobState::default();
        let mut spec = quick_spec("exact", 400);
        spec.auto_stop = Some(AutoStop { window: 20, rel_eps: 1e-4 });
        let res = run_pipeline(&spec, None, &state).unwrap();
        assert!(res.stopped_early, "a 150-point problem must plateau well before 400 iters");
        assert!(res.iters_run < 400);
    }

    #[test]
    fn pending_update_applies_mid_run() {
        // Queue an eta/iters update before starting: the drive loop must
        // apply it at the first step boundary, so the run ends at the
        // updated iteration count.
        let state = JobState::default();
        state.push_update(ParamUpdate { iters: Some(25), ..Default::default() });
        let res = run_pipeline(&quick_spec("bh-0.5", 500), None, &state).unwrap();
        assert_eq!(res.iters_run, 25, "updated iters must cap the run");
        assert!(!res.stopped_early, "shortened, not stopped");
        assert_eq!(state.phase(), JobPhase::Done);
    }

    #[test]
    fn cached_pipeline_skips_similarities_and_matches_uncached() {
        let cache = crate::coordinator::simcache::SimilarityCache::new(4);
        let spec = quick_spec("bh-0.5", 40);
        let a = run_pipeline_cached(&spec, None, &JobState::default(), Some(&cache)).unwrap();
        assert!(!a.timings.sim_cache_hit, "first run must miss");
        assert_eq!(cache.len(), 1);
        let b = run_pipeline_cached(&spec, None, &JobState::default(), Some(&cache)).unwrap();
        assert!(b.timings.sim_cache_hit, "identical second run must hit");
        assert_eq!(b.timings.perplexity_s, 0.0);
        // Same P + same optimiser seed ⇒ bit-identical embedding.
        assert_eq!(a.embedding, b.embedding, "cache hit must not change the result");
        // A different perplexity (different k) is a different key.
        let mut other = quick_spec("bh-0.5", 40);
        other.perplexity = 12.0;
        let c = run_pipeline_cached(&other, None, &JobState::default(), Some(&cache)).unwrap();
        assert!(!c.timings.sim_cache_hit, "different perplexity/k must miss");
        assert_eq!(cache.stats(), (1, 2));
        assert_eq!(cache.computes(), 2);
    }

    #[test]
    fn perplexity_tweak_reuses_the_knn_graph() {
        // ROADMAP (b): two perplexities with the same effective k share
        // one level-1 kNN graph; only the fused P build re-runs.
        let cache = crate::coordinator::simcache::SimilarityCache::new(4);
        let spec = quick_spec("bh-0.5", 30);
        let a = run_pipeline_cached(&spec, None, &JobState::default(), Some(&cache)).unwrap();
        assert!(!a.timings.sim_cache_hit && !a.timings.knn_cache_hit);
        let mut tweaked = quick_spec("bh-0.5", 30);
        tweaked.perplexity = 10.2; // floor(3µ) = 30 either way: same graph key
        let b = run_pipeline_cached(&tweaked, None, &JobState::default(), Some(&cache)).unwrap();
        assert!(!b.timings.sim_cache_hit, "different perplexity misses the P level");
        assert!(b.timings.knn_cache_hit, "... but shares the level-1 kNN graph");
        assert_eq!(cache.graph_stats().computes, 1, "one kNN for the sweep");
        assert_eq!(cache.computes(), 2, "two P builds");
    }

    #[test]
    fn spec_resume_from_and_y0_feed_the_session() {
        let spec = quick_spec("bh-0.5", 40);
        let full = run_pipeline(&spec, None, &JobState::default()).unwrap();

        // Re-run the first 20 iterations by hand and checkpoint them.
        let state = JobState::default();
        let mut timings = StageTimings::default();
        let prep = prepare_similarities(&spec, &state, None, &mut timings).unwrap();
        let mut session = begin_session(&spec, prep.p, None).unwrap();
        while session.iter() < 20 {
            session.step().unwrap();
        }
        let blob = session.checkpoint().to_bytes();

        // A job submitted with resume_from finishes bit-identically to
        // the uninterrupted run.
        let mut resumed = quick_spec("bh-0.5", 40);
        resumed.resume_from = Some(blob);
        let res = run_pipeline(&resumed, None, &JobState::default()).unwrap();
        assert_eq!(res.embedding, full.embedding, "resume must be bit-identical");
        assert_eq!(res.iters_run, 40);

        // y0: a client-supplied layout is the session's starting point
        // (a 0-iteration job hands it straight back).
        let mut warm = quick_spec("bh-0.5", 0);
        warm.y0 = Some(full.embedding.clone());
        let res = run_pipeline(&warm, None, &JobState::default()).unwrap();
        assert_eq!(res.embedding, full.embedding);

        // A malformed resume blob fails the job cleanly at begin.
        let mut bad = quick_spec("bh-0.5", 10);
        bad.resume_from = Some(b"definitely not a checkpoint".to_vec());
        assert!(run_pipeline(&bad, None, &JobState::default()).is_err());
    }

    #[test]
    fn knn_methods_agree_on_easy_data() {
        let data = crate::data::by_name("gaussians", 200, 1).unwrap();
        let e = compute_knn(&data, KnnMethod::Brute, 10, 0);
        let v = compute_knn(&data, KnnMethod::VpTree, 10, 0);
        let f = compute_knn(&data, KnnMethod::KdForest, 10, 0);
        assert!(v.recall_against(&e) > 0.999, "vptree exactness");
        assert!(f.recall_against(&e) > 0.85, "kdforest recall");
    }
}
