//! Executable cache + typed step execution over PJRT.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context};

use super::manifest::{ArtifactSpec, Manifest};

/// Evolving optimiser state, mirrored on the host. Row-major `(n, 2)`.
#[derive(Debug, Clone)]
pub struct StepState {
    pub n: usize,
    pub y: Vec<f32>,
    pub vel: Vec<f32>,
    pub gains: Vec<f32>,
}

impl StepState {
    /// Fresh state for `n` padded points: zero velocity, unit gains on
    /// real points (`mask` decides which), zero on padding.
    pub fn new(y: Vec<f32>, mask: &[f32]) -> Self {
        let n = mask.len();
        assert_eq!(y.len(), 2 * n, "y must be (n,2) row-major");
        let mut gains = vec![0.0f32; 2 * n];
        for (i, &m) in mask.iter().enumerate() {
            if m > 0.0 {
                gains[2 * i] = 1.0;
                gains[2 * i + 1] = 1.0;
            }
        }
        Self { n, y, vel: vec![0.0; 2 * n], gains }
    }
}

/// Per-step scalar outputs (the tensors stay in `StepState`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOutputs {
    /// Normalisation Ẑ (Eq. 13).
    pub zhat: f32,
    /// Neighbour-restricted KL estimate.
    pub kl: f32,
    /// Post-update bounding box `[min_x, min_y, max_x, max_y]`.
    pub bbox: [f32; 4],
}

impl StepOutputs {
    /// Embedding diameter (max bbox side) — drives the adaptive-ρ policy.
    pub fn diameter(&self) -> f32 {
        (self.bbox[2] - self.bbox[0]).max(self.bbox[3] - self.bbox[1])
    }
}

/// Device-resident per-job tensors, uploaded once and reused each step.
pub struct StaticArgs {
    pub n: usize,
    pub k: usize,
    mask: xla::PjRtBuffer,
    nbr_idx: xla::PjRtBuffer,
    nbr_p: xla::PjRtBuffer,
    /// Host copy of the mask (needed when switching buckets).
    pub mask_host: Vec<f32>,
}

/// A compiled artifact bound to its spec.
pub struct StepExe {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Shared PJRT runtime: one CPU client + a lazy compile cache.
///
/// Thread-safety: the PJRT CPU client is internally synchronised (it is
/// the same TFRT CPU client JAX uses from many Python threads); the Rust
/// wrapper types merely hold pointers. We therefore mark the runtime
/// `Send + Sync` and protect the *cache map* with a mutex.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<StepExe>>>,
    /// Device-resident rank-0 f32 scalars, keyed by bit pattern. The GD
    /// schedules (eta, momentum, exaggeration) only take a handful of
    /// distinct values per run, so caching removes three host→device
    /// uploads from every iteration (§Perf).
    scalar_cache: Mutex<HashMap<u32, Arc<xla::PjRtBuffer>>>,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for StepExe {}
unsafe impl Sync for StepExe {}
unsafe impl Send for StaticArgs {}
unsafe impl Sync for StaticArgs {}

impl Runtime {
    /// Create a runtime over the artifact directory (must hold a manifest).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            scalar_cache: Mutex::new(HashMap::new()),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }

    /// Get (lazily compiling) the executable for an artifact name.
    pub fn executable(&self, name: &str) -> anyhow::Result<Arc<StepExe>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("unknown artifact '{name}'"))?
            .clone();
        let path = self.manifest.path_of(&spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        let entry = Arc::new(StepExe { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), entry.clone());
        Ok(entry)
    }

    /// Get the single-step executable for an exact (n, grid) pair.
    pub fn step_executable(&self, n: usize, grid: usize) -> anyhow::Result<Arc<StepExe>> {
        let spec = self
            .manifest
            .find_step(n, grid)
            .with_context(|| format!("no step artifact for n={n} grid={grid}"))?;
        self.executable(&spec.name.clone())
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Device-resident rank-0 f32 scalar, cached by bit pattern.
    fn scalar_buffer(&self, v: f32) -> anyhow::Result<Arc<xla::PjRtBuffer>> {
        let key = v.to_bits();
        if let Some(b) = self.scalar_cache.lock().unwrap().get(&key) {
            return Ok(b.clone());
        }
        let b = Arc::new(self.client.buffer_from_host_buffer(&[v], &[], None)?);
        self.scalar_cache.lock().unwrap().insert(key, b.clone());
        Ok(b)
    }

    /// Upload the static per-job tensors for bucket `n` (device-resident).
    ///
    /// `mask`: (n,) 1/0; `nbr_idx`: (n,k) row-major i32; `nbr_p`: (n,k)
    /// row-major f32 with exact zeros on padded slots.
    pub fn upload_static(
        &self,
        mask: &[f32],
        nbr_idx: &[i32],
        nbr_p: &[f32],
        k: usize,
    ) -> anyhow::Result<StaticArgs> {
        let n = mask.len();
        if nbr_idx.len() != n * k || nbr_p.len() != n * k {
            bail!(
                "static arg shape mismatch: n={n} k={k} idx={} p={}",
                nbr_idx.len(),
                nbr_p.len()
            );
        }
        Ok(StaticArgs {
            n,
            k,
            mask: self.client.buffer_from_host_buffer(mask, &[n], None)?,
            nbr_idx: self.client.buffer_from_host_buffer(nbr_idx, &[n, k], None)?,
            nbr_p: self.client.buffer_from_host_buffer(nbr_p, &[n, k], None)?,
            mask_host: mask.to_vec(),
        })
    }

    /// Execute one optimiser step (or a fused multi-step artifact).
    ///
    /// Argument order must match `aot.ARG_NAMES`:
    /// `y, vel, gains, mask, nbr_idx, nbr_p, eta, momentum, exaggeration`.
    /// State tensors are updated in place from the device outputs.
    pub fn run_step(
        &self,
        exe: &StepExe,
        state: &mut StepState,
        statics: &StaticArgs,
        eta: f32,
        momentum: f32,
        exaggeration: f32,
    ) -> anyhow::Result<StepOutputs> {
        let n = exe.spec.n;
        if state.n != n || statics.n != n {
            bail!(
                "bucket mismatch: artifact n={n}, state n={}, statics n={}",
                state.n,
                statics.n
            );
        }
        let up = |data: &[f32], dims: &[usize]| {
            self.client.buffer_from_host_buffer(data, dims, None)
        };
        let y = up(&state.y, &[n, 2])?;
        let vel = up(&state.vel, &[n, 2])?;
        let gains = up(&state.gains, &[n, 2])?;
        let eta_b = self.scalar_buffer(eta)?;
        let mom_b = self.scalar_buffer(momentum)?;
        let ex_b = self.scalar_buffer(exaggeration)?;

        let args: Vec<&xla::PjRtBuffer> = vec![
            &y,
            &vel,
            &gains,
            &statics.mask,
            &statics.nbr_idx,
            &statics.nbr_p,
            eta_b.as_ref(),
            mom_b.as_ref(),
            ex_b.as_ref(),
        ];
        let out = exe.exe.execute_b(&args).context("PJRT execute")?;
        let result = out
            .first()
            .and_then(|r| r.first())
            .context("execute returned no outputs")?
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 6 {
            bail!("expected 6 outputs (y,vel,gains,zhat,kl,bbox), got {}", parts.len());
        }
        state.y = parts[0].to_vec::<f32>()?;
        state.vel = parts[1].to_vec::<f32>()?;
        state.gains = parts[2].to_vec::<f32>()?;
        let zhat = parts[3].to_vec::<f32>()?[0];
        let kl = parts[4].to_vec::<f32>()?[0];
        let bbox_v = parts[5].to_vec::<f32>()?;
        Ok(StepOutputs { zhat, kl, bbox: [bbox_v[0], bbox_v[1], bbox_v[2], bbox_v[3]] })
    }
}
