//! PJRT runtime (L3 ↔ L2 boundary).
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (`artifacts/*.hlo.txt` + `manifest.json`), compiles them lazily on a
//! shared PJRT CPU client, and exposes a typed `step` interface to the
//! optimiser. Static per-job tensors (neighbour lists, joint
//! probabilities, point mask) are uploaded once as device-resident
//! buffers and reused by every iteration (`execute_b`); only the evolving
//! embedding state and three scalars cross the host boundary per step.

mod exec;
mod manifest;

pub use exec::{Runtime, StaticArgs, StepExe, StepOutputs, StepState};
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True when an artifact directory (with a manifest) is present; tests and
/// examples use this to skip gracefully before `make artifacts` has run.
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}

/// Locate the artifact directory: `$GPGPU_SNE_ARTIFACTS`, then
/// `./artifacts`, then `../artifacts` (for tests executed from target/).
pub fn locate_artifacts() -> Option<String> {
    if let Ok(d) = std::env::var("GPGPU_SNE_ARTIFACTS") {
        if artifacts_available(&d) {
            return Some(d);
        }
    }
    for d in [DEFAULT_ARTIFACT_DIR, "../artifacts", "../../artifacts"] {
        if artifacts_available(d) {
            return Some(d.to_string());
        }
    }
    None
}
