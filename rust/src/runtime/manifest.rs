//! Artifact manifest: what `python/compile/aot.py` built and where.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json;

/// One AOT-lowered executable variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// Unique name, e.g. `step_n1024_k96_g64`.
    pub name: String,
    /// HLO-text file name inside the artifact directory.
    pub file: String,
    /// `"step"` (single iteration) or `"steps"` (fused scan).
    pub kind: String,
    /// Point-count bucket N (shapes are padded to this).
    pub n: usize,
    /// Neighbour list width K.
    pub k: usize,
    /// Field texture side length G.
    pub grid: usize,
    /// Iterations fused per execute call (1 for `step`).
    pub steps: usize,
}

/// Parsed `manifest.json` plus the directory it lives in.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub arg_names: Vec<String>,
    pub out_names: Vec<String>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let str_list = |key: &str| -> anyhow::Result<Vec<String>> {
            Ok(v.get(key)
                .and_then(json::Json::as_arr)
                .with_context(|| format!("manifest missing '{key}'"))?
                .iter()
                .filter_map(|s| s.as_str().map(str::to_string))
                .collect())
        };
        let arg_names = str_list("arg_names")?;
        let out_names = str_list("out_names")?;

        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .and_then(json::Json::as_arr)
            .context("manifest missing 'artifacts'")?
        {
            let field = |k: &str| -> anyhow::Result<usize> {
                a.num_field(k)
                    .map(|n| n as usize)
                    .with_context(|| format!("artifact missing '{k}'"))
            };
            let spec = ArtifactSpec {
                name: a.str_field("name").context("artifact missing 'name'")?.to_string(),
                file: a.str_field("file").context("artifact missing 'file'")?.to_string(),
                kind: a.str_field("kind").unwrap_or("step").to_string(),
                n: field("n")?,
                k: field("k")?,
                grid: field("grid")?,
                steps: field("steps").unwrap_or(1),
            };
            if !dir.join(&spec.file).exists() {
                bail!("manifest lists {} but {} is missing", spec.name, spec.file);
            }
            artifacts.push(spec);
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts — rerun `make artifacts`");
        }
        Ok(Self { dir, arg_names, out_names, artifacts })
    }

    /// All single-step variants.
    pub fn steps(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind == "step")
    }

    /// The smallest point bucket that fits `n_real` (single-step variants).
    pub fn bucket_for(&self, n_real: usize) -> Option<usize> {
        self.steps().map(|a| a.n).filter(|&n| n >= n_real).min().or_else(|| {
            // Larger than every bucket: take the biggest (caller chunks or fails).
            self.steps().map(|a| a.n).max()
        })
    }

    /// Largest point bucket available (capacity of the gpgpu engine).
    pub fn max_bucket(&self) -> usize {
        self.steps().map(|a| a.n).max().unwrap_or(0)
    }

    /// Grid sizes available for point bucket `n` (ascending).
    pub fn grids_for(&self, n: usize) -> Vec<usize> {
        let mut g: Vec<usize> = self.steps().filter(|a| a.n == n).map(|a| a.grid).collect();
        g.sort_unstable();
        g.dedup();
        g
    }

    /// Find the single-step artifact for an exact (n, grid) pair.
    pub fn find_step(&self, n: usize, grid: usize) -> Option<&ArtifactSpec> {
        self.steps().find(|a| a.n == n && a.grid == grid)
    }

    /// Find a fused multi-step artifact for bucket `n`, if any was built.
    pub fn find_fused(&self, n: usize) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.kind == "steps" && a.n == n)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_manifest(dir: &Path, names: &[(&str, usize, usize)]) {
        let mut arts = Vec::new();
        for (name, n, g) in names {
            let file = format!("{name}.hlo.txt");
            std::fs::File::create(dir.join(&file)).unwrap().write_all(b"HloModule x").unwrap();
            arts.push(format!(
                r#"{{"name":"{name}","file":"{file}","kind":"step","n":{n},"k":96,"grid":{g},"steps":1}}"#
            ));
        }
        let text = format!(
            r#"{{"version":1,"arg_names":["y"],"out_names":["y"],"artifacts":[{}]}}"#,
            arts.join(",")
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_and_queries() {
        let dir = std::env::temp_dir().join(format!("gpgpu_sne_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(
            &dir,
            &[("a", 1024, 32), ("b", 1024, 64), ("c", 4096, 32), ("d", 4096, 64)],
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.bucket_for(1000), Some(1024));
        assert_eq!(m.bucket_for(1025), Some(4096));
        assert_eq!(m.bucket_for(999_999), Some(4096)); // clamps to biggest
        assert_eq!(m.grids_for(1024), vec![32, 64]);
        assert_eq!(m.find_step(4096, 64).unwrap().name, "d");
        assert!(m.find_step(4096, 128).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join(format!("gpgpu_sne_manifest2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir, &[("a", 1024, 32)]);
        std::fs::remove_file(dir.join("a.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
