//! `pallas-bench-trend` — bench-history trend table and the CI
//! regression gate.
//!
//! Reads a `BENCH_history.jsonl` (one `{"commit","date","bench":...}`
//! object per line, newest last), computes per-metric deltas of the
//! newest entry against a baseline (`--baseline <commit-prefix>`, or
//! the adjacent previous entry), renders a markdown trend table, and
//! exits 1 when any gated metric regressed beyond its rule's
//! tolerance. See [`gpgpu_sne::tools::benchtrend`] for the rule set.

use gpgpu_sne::tools::benchtrend::{analyze, default_rules, parse_history, render_markdown};
use gpgpu_sne::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let history = args.str("history", "BENCH_history.jsonl", "bench history file (jsonl)");
    let baseline = args.opt_str("baseline", "baseline commit prefix (default: previous entry)");
    let all = args.flag("all", "show ungated metrics in the table too");
    let text = match std::fs::read_to_string(&history) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: read {history}: {e}");
            std::process::exit(2);
        }
    };
    let verdict = parse_history(&text)
        .and_then(|entries| analyze(&entries, baseline.as_deref(), &default_rules()));
    match verdict {
        Ok(None) => {
            println!("bench history has fewer than two entries; nothing to compare");
        }
        Ok(Some(a)) => {
            print!("{}", render_markdown(&a, all));
            let regressions = a.regressions();
            if !regressions.is_empty() {
                for d in &regressions {
                    eprintln!(
                        "regression: {} {:.4} -> {:.4} (ratio {:.3})",
                        d.path, d.old, d.new, d.ratio
                    );
                }
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    }
}
