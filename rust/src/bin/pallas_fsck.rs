//! `pallas-fsck` — offline integrity checker for a serve/router
//! `--state-dir`.
//!
//! Walks `simstore/`, `jobs/`, and `cluster-journal/`, verifying every
//! record's framing (magic, version, length, checksum), deep structure,
//! and key echo, and reporting orphaned `*.tmp.*` leftovers. **Dry-run
//! by default**: without `--repair` or `--compact` the pass is strictly
//! read-only and leaves every byte in place. Exit code 0 when the store
//! is clean (or was just made clean), 1 when defects remain.

use std::path::PathBuf;

use gpgpu_sne::tools::fsck::{run_fsck, FsckOptions};
use gpgpu_sne::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let state_dir = PathBuf::from(args.str("state-dir", "state", "state directory to check"));
    let opts = FsckOptions {
        repair: args.flag(
            "repair",
            "delete corrupt records and tmp orphans; rename misplaced records to their key-echo name",
        ),
        compact: args.flag("compact", "also rewrite healthy records atomically"),
    };
    if !state_dir.exists() {
        eprintln!("error: state dir {} does not exist", state_dir.display());
        std::process::exit(2);
    }
    match run_fsck(&state_dir, &opts) {
        Ok(report) => {
            println!("{}", report.to_json());
            // After a mutating pass the defects listed were removed; a
            // dry run leaves them on disk, so their presence is the
            // verdict either way.
            if !report.clean() && !(opts.repair || opts.compact) {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    }
}
