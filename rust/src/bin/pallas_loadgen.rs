//! `pallas-loadgen` — deterministic seeded load/chaos generator for a
//! live `gpgpu-sne serve` (or `router`) endpoint.
//!
//! See [`gpgpu_sne::tools::loadgen`] for the model. Exit code 0 when
//! every hard invariant held, 1 otherwise; the JSON summary goes to
//! stdout either way.

use std::time::Duration;

use gpgpu_sne::tools::loadgen::{run, LoadgenConfig};
use gpgpu_sne::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cfg = LoadgenConfig {
        addr: args.str("addr", "127.0.0.1:7001", "serve/router endpoint to drive"),
        seed: args.get("seed", 1u64, "plan seed; same seed => same job accounting"),
        clients: args.get("clients", 8usize, "concurrent client connections"),
        jobs_per_client: args.get("jobs", 2usize, "jobs each client submits in sequence"),
        n: args.get("n", 64usize, "points per submitted dataset"),
        iters: args.get("iters", 120usize, "iterations for bounded (run/watch) jobs"),
        fault_spec: args.opt_str("fault", "fault spec to arm mid-run (chaos mode)"),
        timeout: Duration::from_secs(args.get(
            "timeout-s",
            300u64,
            "hard wall clock for the whole run; exceeding it fails",
        )),
        skew_tolerance: args.get(
            "skew-tolerance",
            4.0f64,
            "multiplicative band around the nominal 3:1 interleave",
        ),
    };
    match run(&cfg) {
        Ok(summary) => {
            println!("{}", summary.to_json(&cfg));
            if !summary.ok() {
                for v in &summary.violations {
                    eprintln!("violation: {v}");
                }
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    }
}
