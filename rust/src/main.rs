//! gpgpu-sne — CLI for the reproduction of "GPGPU Linear Complexity t-SNE
//! Optimization" (Pezzotti et al., 2018).
//!
//! Subcommands:
//!   embed     run one embedding job and write the result
//!   serve     run the progressive embedding service over TCP
//!   router    front N serve workers: fingerprint routing, migration, failover
//!   info      show artifact / runtime / dataset information
//!   datasets  list the evaluation datasets (Table 1)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use gpgpu_sne::coordinator::{job::AutoStop, progress::JobState, run_pipeline, JobSpec};
use gpgpu_sne::embed::OptParams;
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::cli::Args;
use gpgpu_sne::util::image;
use gpgpu_sne::util::timer::fmt_secs;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "embed" => cmd_embed(&args),
        "serve" => cmd_serve(&args),
        "router" => cmd_router(&args),
        "info" => cmd_info(&args),
        "datasets" => cmd_datasets(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "gpgpu-sne — field-based linear-complexity t-SNE (Pezzotti et al. 2018)\n\n\
         usage: gpgpu-sne <embed|serve|info|datasets> [options]\n\n\
         embed    --dataset mnist --n 2000 --engine gpgpu|fieldfft|fieldcpu|bh-0.5|bh-0.1|exact|tsne-cuda-0.5\n\
                  --iters 1000 --perplexity 30 --knn brute|vptree|kdforest --seed 42\n\
                  --auto-stop-window 30 [--auto-stop-eps 1e-5]\n\
                  --out embedding.csv --image embedding.pgm\n\
         serve    --addr 127.0.0.1:7878 --max-concurrent 2\n\
                  --state-dir state/ --journal-every 50\n\
                  --metrics-dump metrics.json --trace-ring 4096\n\
                  --max-queue-depth 256 --fault point=trigger[,...]\n\
                  (cooperatively scheduled sessions; TCP commands incl.\n\
                   pause/resume/update/checkpoint/metrics/trace/fault,\n\
                   resumable submits — see docs/PROTOCOL.md; --state-dir\n\
                   makes jobs and the similarity store survive restarts;\n\
                   `shutdown` or SIGTERM drains gracefully;\n\
                   --router <addr> announces this worker to a router)\n\
         router   --addr 127.0.0.1:7979 --workers host:port[,host:port...]\n\
                  --heartbeat-ms 1000 --heartbeat-timeout-ms 3000\n\
                  --state-dir state/ --fault point=trigger[,...]\n\
                  (shards submits across workers by dataset fingerprint,\n\
                   proxies job commands, replicates checkpoints, migrates\n\
                   live sessions, fails jobs over from dead workers —\n\
                   see docs/PROTOCOL.md `migrate`/`cluster_stats`/`hello`)\n\
         info     (artifact + platform report)\n\
         datasets (Table 1)\n\n\
         Ops tools ship as separate binaries (README § Operations):\n\
         pallas-loadgen (seeded load/chaos against a live serve),\n\
         pallas-bench-trend (bench-history regression gate),\n\
         pallas-fsck (state-dir integrity; dry-run by default).\n\n\
         Run `make artifacts` first to enable the gpgpu engine."
    );
}

/// Set by the SIGTERM handler, polled by the drain watcher in
/// [`cmd_serve`]. A signal handler may only do async-signal-safe work,
/// so it flips this flag and nothing else.
static TERM: AtomicBool = AtomicBool::new(false);

extern "C" fn on_term(_sig: i32) {
    TERM.store(true, Ordering::SeqCst);
}

/// Install [`on_term`] for SIGTERM through libc's `signal(2)`, declared
/// directly — the build stays offline and crate-free.
fn install_sigterm_handler() {
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
        }
    }
}

fn load_runtime() -> Option<Arc<Runtime>> {
    let dir = runtime::locate_artifacts()?;
    match Runtime::new(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("warning: artifacts at {dir} unusable: {e:#}");
            None
        }
    }
}

fn spec_from_args(args: &Args) -> anyhow::Result<JobSpec> {
    let mut spec = JobSpec {
        dataset: args.str("dataset", "mnist", "dataset name (see `datasets`)"),
        n: args.get("n", 2000usize, "number of points"),
        engine: args.str("engine", "fieldcpu", "optimiser engine"),
        perplexity: args.get("perplexity", 30.0f32, "perplexity mu"),
        knn: args.str("knn", "kdforest", "knn method").parse()?,
        snapshot_every: args.get("snapshot-every", 100usize, "snapshot cadence"),
        seed: args.get("seed", 42u64, "random seed"),
        ..Default::default()
    };
    spec.params = OptParams {
        iters: args.get("iters", 1000usize, "gradient-descent iterations"),
        eta: args.get("eta", 200.0f32, "learning rate"),
        exaggeration: args.get("exaggeration", 12.0f32, "early exaggeration"),
        exaggeration_iters: args.get("exaggeration-iters", 250usize, "exaggeration phase"),
        seed: spec.seed,
        ..Default::default()
    };
    // A-tSNE automatic early termination: stop once the KL estimate
    // plateaus (after exaggeration lifts).
    if let Some(window) =
        args.opt_get::<usize>("auto-stop-window", "enable auto-stop: KL plateau window (iters)")
    {
        spec.auto_stop = Some(AutoStop {
            window: window.max(1),
            rel_eps: args.get("auto-stop-eps", 1e-5f64, "auto-stop relative KL improvement"),
        });
    }
    Ok(spec)
}

fn cmd_embed(args: &Args) -> anyhow::Result<()> {
    let spec = spec_from_args(args)?;
    let out = args.opt_str("out", "CSV output path");
    let img = args.opt_str("image", "PGM scatterplot path");
    args.finish_help("Run one embedding job");

    let rt = if spec.engine == "gpgpu" { load_runtime() } else { None };
    if spec.engine == "gpgpu" && rt.is_none() {
        anyhow::bail!("gpgpu engine requires artifacts — run `make artifacts`");
    }
    println!(
        "embedding {} n={} engine={} perplexity={} iters={}",
        spec.dataset, spec.n, spec.engine, spec.perplexity, spec.params.iters
    );
    let state = JobState::default();
    // Progress printer thread.
    let rx = state.snapshots.subscribe();
    let printer = std::thread::spawn(move || {
        let lag = gpgpu_sne::obs::registry().histogram("snapshot.deliver_lag_ns");
        for s in rx {
            lag.record(gpgpu_sne::obs::now_ns().saturating_sub(s.published_ns));
            eprintln!("  iter {:>5}  KL≈{:.4}  t={}", s.iter, s.kl_est, fmt_secs(s.elapsed_s));
        }
    });
    let res = run_pipeline(&spec, rt, &state)?;
    drop(state);
    let _ = printer.join();

    println!(
        "done: {} iters, KL≈{:.4}; stages: {}",
        res.iters_run,
        res.kl_est,
        res.timings.human_summary(),
    );
    if let Some(path) = out {
        let n = res.embedding.len() / 2;
        let mut cols = vec![Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)];
        for i in 0..n {
            cols[0].push(res.embedding[2 * i] as f64);
            cols[1].push(res.embedding[2 * i + 1] as f64);
            cols[2].push(res.labels[i] as f64);
        }
        image::write_csv(&path, &["x", "y", "label"], &cols)?;
        println!("wrote {path}");
    }
    if let Some(path) = img {
        image::write_embedding_pgm(&path, &res.embedding, &res.labels, 640)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let addr = args.str("addr", "127.0.0.1:7878", "bind address");
    let maxc = args.get("max-concurrent", 2usize, "concurrent optimisations");
    let state_dir = args.opt_str(
        "state-dir",
        "durable state directory: checkpoint journal + on-disk similarity \
         store; restarts re-admit interrupted jobs as resumable",
    );
    let journal_every =
        args.get("journal-every", 50usize, "journal running jobs every N iterations");
    let metrics_dump =
        args.opt_str("metrics-dump", "write a JSON metrics snapshot to this path every 5 s");
    let trace_ring = args.get(
        "trace-ring",
        gpgpu_sne::obs::trace::DEFAULT_RING_CAPACITY,
        "per-thread trace-ring capacity, in span events",
    );
    let max_queue = args.get(
        "max-queue-depth",
        gpgpu_sne::coordinator::ServiceConfig::default().max_queue_depth,
        "admission cap: shed submits once the ready queue holds this many jobs",
    );
    let fault = args.opt_str(
        "fault",
        "arm fault points at startup, e.g. store.write=prob:0.1@7,net.stall=every:5 \
         (see docs/PROTOCOL.md `fault`)",
    );
    let router = args.opt_str(
        "router",
        "announce this worker to a `pallas router` at this address \
         (periodic `hello`, which doubles as registration after a router restart)",
    );
    args.finish_help("Serve the progressive embedding service over TCP");
    let rt = load_runtime();
    println!(
        "serve: runtime={}, protocol: one JSON object per line (see docs/PROTOCOL.md)",
        rt.as_ref().map(|r| r.platform()).unwrap_or_else(|| "none (CPU engines only)".into())
    );
    match &state_dir {
        Some(dir) => println!("durable state: {dir} (journal every {journal_every} iters)"),
        None => println!("durable state: off (pass --state-dir to survive restarts)"),
    }
    let cfg = gpgpu_sne::coordinator::ServiceConfig {
        max_concurrent: maxc,
        state_dir: state_dir.map(std::path::PathBuf::from),
        journal_every,
        trace_ring,
        max_queue_depth: max_queue,
        ..Default::default()
    };
    let svc = Arc::new(gpgpu_sne::coordinator::EmbeddingService::with_config(rt, cfg));
    if let Some(spec) = fault {
        gpgpu_sne::coordinator::faultinject::arm_spec(&spec)
            .map_err(|e| anyhow::anyhow!("--fault: {e}"))?;
        println!("fault points armed: {spec}");
    }
    if let Some(path) = metrics_dump {
        println!("metrics dump: {path} (every 5 s; same shape as the `metrics` command)");
        let svc = svc.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(5));
            if let Err(e) = std::fs::write(&path, format!("{}\n", svc.metrics_json())) {
                eprintln!("warning: metrics dump to {path} failed: {e}");
                return;
            }
        });
    }
    // SIGTERM = the same graceful drain as the `shutdown` command:
    // stop admitting, park + journal every live session at its next
    // step boundary, then wake the accept loop so `serve` returns and
    // a restart (same --state-dir) resumes every job bit-identically.
    install_sigterm_handler();
    let bound: Arc<std::sync::Mutex<Option<std::net::SocketAddr>>> = Arc::default();
    {
        let svc = svc.clone();
        let bound = bound.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_millis(100));
            if TERM.load(Ordering::SeqCst) {
                eprintln!("SIGTERM: draining (parking + journalling live jobs)");
                let parked = svc.drain(std::time::Duration::from_secs(30));
                eprintln!("drained: {parked} job(s) parked, resumable on restart");
                if let Some(addr) = *bound.lock().unwrap() {
                    let _ = std::net::TcpStream::connect(addr);
                }
                return;
            }
        });
    }
    // Worker-side cluster membership is one outbound `hello` loop: the
    // router learns (or re-learns, after its own restart) this worker's
    // address; everything else — routing, replication, migration — is
    // router-driven over the plain client protocol.
    if let Some(router_addr) = router {
        let bound = bound.clone();
        std::thread::spawn(move || {
            let mut announced = false;
            loop {
                let Some(addr) = *bound.lock().unwrap() else {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    continue;
                };
                let line = format!(r#"{{"cmd":"hello","addr":"{addr}"}}"#);
                match gpgpu_sne::cluster::rpc(&router_addr, &line, std::time::Duration::from_secs(5)) {
                    Ok(_) if !announced => {
                        announced = true;
                        eprintln!("announced to router {router_addr}");
                    }
                    Ok(_) => {}
                    Err(e) if announced => {
                        announced = false;
                        eprintln!("warning: router {router_addr} unreachable ({e:#}); retrying");
                    }
                    Err(_) => {}
                }
                std::thread::sleep(std::time::Duration::from_secs(2));
            }
        });
    }
    gpgpu_sne::coordinator::protocol::serve(svc, &addr, |a| {
        *bound.lock().unwrap() = Some(a);
        println!("listening on {a}");
    })
}

fn cmd_router(args: &Args) -> anyhow::Result<()> {
    let addr = args.str("addr", "127.0.0.1:7979", "bind address");
    let workers =
        args.opt_str("workers", "comma-separated worker addresses to register at startup");
    let hb_ms = args.get("heartbeat-ms", 1000u64, "heartbeat cadence (0 disables the loop)");
    let hb_timeout_ms = args.get(
        "heartbeat-timeout-ms",
        3000u64,
        "declare a worker dead (and fail its jobs over) after this much silence",
    );
    let state_dir = args.opt_str(
        "state-dir",
        "replicate worker checkpoints into <dir>/cluster-journal; \
         a restarted router re-admits journalled jobs",
    );
    let fault = args.opt_str(
        "fault",
        "arm fault points at startup, e.g. cluster.heartbeat.drop=every:3 \
         (see docs/PROTOCOL.md `fault`)",
    );
    args.finish_help("Route submits across serve workers by dataset fingerprint");
    let cfg = gpgpu_sne::cluster::RouterConfig {
        heartbeat_interval: (hb_ms > 0).then(|| std::time::Duration::from_millis(hb_ms)),
        heartbeat_timeout: std::time::Duration::from_millis(hb_timeout_ms),
        state_dir: state_dir.map(std::path::PathBuf::from),
        ..Default::default()
    };
    let router = Arc::new(gpgpu_sne::cluster::Router::new(cfg));
    if let Some(spec) = fault {
        gpgpu_sne::coordinator::faultinject::arm_spec(&spec)
            .map_err(|e| anyhow::anyhow!("--fault: {e}"))?;
        println!("fault points armed: {spec}");
    }
    for w in workers.as_deref().unwrap_or("").split(',').filter(|s| !s.trim().is_empty()) {
        let id = router.register_worker(w.trim());
        println!("worker {id}: {}", w.trim());
    }
    let readmitted = router.recover();
    if readmitted > 0 {
        println!("re-admitted {readmitted} journalled job(s) from the cluster journal");
    }
    router.spawn_heartbeat();
    router.serve(&addr, |a| {
        println!("router listening on {a} (workers join with `serve --router {a}`)");
    })
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    args.finish_help("Show artifact and runtime information");
    match runtime::locate_artifacts() {
        None => println!("artifacts: none found (run `make artifacts`)"),
        Some(dir) => {
            let rt = Runtime::new(&dir)?;
            println!("artifacts: {dir}");
            println!("platform:  {}", rt.platform());
            println!("variants:");
            for a in &rt.manifest.artifacts {
                println!(
                    "  {:<28} kind={:<5} n={:<6} k={:<3} grid={:<4} steps={}",
                    a.name, a.kind, a.n, a.k, a.grid, a.steps
                );
            }
        }
    }
    println!("threads:   {}", gpgpu_sne::util::parallel::num_threads());
    Ok(())
}

fn cmd_datasets(args: &Args) -> anyhow::Result<()> {
    args.finish_help("List evaluation datasets (paper Table 1)");
    println!("{:<20} {:>10} {:>6}   substitution", "dataset", "paper N", "dims");
    for (name, n, d) in gpgpu_sne::data::TABLE1 {
        let ds = gpgpu_sne::data::by_name(name, 16, 0)?;
        println!("{name:<20} {n:>10} {d:>6}   generated as '{}'", ds.name);
    }
    Ok(())
}
