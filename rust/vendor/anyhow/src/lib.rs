//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build environment for this repo is fully offline, so instead of the
//! crates.io `anyhow` we ship the small slice the codebase actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros. Semantics
//! mirror upstream where it matters:
//!
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole cause chain joined by `": "`.
//! * Any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`, capturing its `source()` chain.
//! * `Error` itself does NOT implement `std::error::Error` (exactly like
//!   upstream), which is what makes the blanket `From` impl coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost-first chain of messages.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return Err($crate::Error::msg(format!($msg)))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return Err($crate::Error::msg(format!($fmt, $($arg)*)))
    };
    ($err:expr $(,)?) => {
        return Err($crate::Error::msg($err))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $msg:literal $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($msg)));
        }
    };
    ($cond:expr, $fmt:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($fmt, $($arg)*)));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        let v: Option<u8> = Some(3);
        assert_eq!(v.with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<u8> {
            ensure!(!flag, "flag was {flag}");
            bail!("always fails with {}", 42);
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", f(false).unwrap_err()), "always fails with 42");
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
    }
}
