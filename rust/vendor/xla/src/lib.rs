//! Offline stub of the `xla` (xla-rs) PJRT API surface used by
//! `gpgpu_sne::runtime`.
//!
//! The real crate links the native XLA/PJRT runtime, which is not part of
//! this container image. This stub is type-compatible with every call site
//! in `runtime/exec.rs` but fails at *runtime* when a PJRT client is
//! requested. That is safe by construction: the runtime layer is only ever
//! reached when `runtime::locate_artifacts()` finds a compiled artifact
//! directory, and producing artifacts requires the same native toolchain —
//! so in an offline build every device path is cleanly skipped and the CPU
//! engines (including the new `fieldfft`) carry the workload.
//!
//! Swapping in the real implementation is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the native crate).

use std::fmt;

/// Error type for every stubbed operation.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Error {
            msg: format!(
                "{what}: the native XLA/PJRT runtime is not available in this offline build \
                 (the `xla` crate is the vendored stub at rust/vendor/xla)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A device (stub; only used as the `Option<&PjRtDevice>` placement hint).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// A device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (stub).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: Clone + Default>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// A parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// The PJRT client (stub): construction always fails, which gates every
/// downstream device path behind a clean error instead of a crash.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline"));
    }
}
