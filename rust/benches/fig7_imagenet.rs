//! Figure 7 — the ImageNet activation datasets (Mixed3a 256-d, Head0
//! 128-d): execution time, final KL and NNP for BH-SNE θ=0.5,
//! t-SNE-CUDA θ=0.0/0.5 (simulated) and the field-based engines — the
//! paper's exact engine lineup for this figure.
//!
//! Expected shape: field-based beats BH by ~two orders of magnitude in
//! time at the full 100k (here: the growing-factor trend over the sweep),
//! with lower KL and better precision/recall than both BH and t-SNE-CUDA.
//!
//!     cargo bench --bench fig7_imagenet [-- --quick]

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::{self, tsnecuda, OptParams};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::metrics::{kl, nnp};
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::bench::{measure_once, quick_mode, Report};
use gpgpu_sne::util::timer::fmt_secs;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let ns: Vec<usize> = if quick { vec![500, 1500] } else { vec![1000, 2500] };
    let iters = if quick { 150 } else { 300 };
    let scale = 1000.0 / iters as f64;

    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    // The paper's Fig. 7 engine set.
    let mut engines = vec!["bh-0.5", "tsne-cuda-0.0", "tsne-cuda-0.5", "fieldcpu", "fieldfft"];
    if rt.is_some() {
        engines.push("gpgpu");
    }

    for dataset in ["imagenet-mixed3a", "imagenet-head0"] {
        let mut time_report = Report::new(
            &format!("Fig7 — time, {dataset} (1000-iter equivalent; * = GPU model)"),
            &engines.iter().map(|s| *s).collect::<Vec<_>>(),
        );
        let mut kl_report = Report::new(
            &format!("Fig7 — final KL, {dataset}"),
            &engines.iter().map(|s| *s).collect::<Vec<_>>(),
        );
        let mut nnp_report = Report::new(
            &format!("Fig7 — NNP mean precision, {dataset}"),
            &engines.iter().map(|s| *s).collect::<Vec<_>>(),
        );
        for &n in &ns {
            let ds = gpgpu_sne::data::by_name(dataset, n, 9)?;
            let knn = compute_knn(&ds, KnnMethod::KdForest, 90.min(n / 2), 9);
            let p = perplexity::joint_p(&knn, 30.0);
            let params = OptParams { iters, ..Default::default() };

            let mut t_cells = Vec::new();
            let mut k_cells = Vec::new();
            let mut n_cells = Vec::new();
            for name in &engines {
                if *name == "gpgpu"
                    && rt.as_ref().map(|r| n > r.manifest.max_bucket()).unwrap_or(true)
                {
                    t_cells.push("—".into());
                    k_cells.push("—".into());
                    n_cells.push("—".into());
                    continue;
                }
                let runtime = if *name == "gpgpu" { rt.clone() } else { None };
                let mut e = embed::by_name(name, runtime)?;
                let mut y = Vec::new();
                let secs = measure_once(|| {
                    y = e.run(&p, &params, None).unwrap();
                }) * scale;
                // t-SNE-CUDA rows report the modelled GPU time.
                if name.starts_with("tsne-cuda") {
                    t_cells.push(format!("{}*", fmt_secs(tsnecuda::TsneCudaSim::modelled_time(secs))));
                } else {
                    t_cells.push(fmt_secs(secs));
                }
                k_cells.push(format!("{:.4}", kl::kl_divergence_exact(&p, &y)));
                let curve = nnp::nnp_curve(&ds, &y, 1000, 0);
                n_cells.push(format!("{:.3}", curve.mean_precision()));
            }
            time_report.row(&format!("N={n}"), t_cells);
            kl_report.row(&format!("N={n}"), k_cells);
            nnp_report.row(&format!("N={n}"), n_cells);
        }
        time_report.print();
        time_report.write_csv(&format!("fig7_time_{dataset}.csv"))?;
        kl_report.print();
        kl_report.write_csv(&format!("fig7_kl_{dataset}.csv"))?;
        nnp_report.print();
        nnp_report.write_csv(&format!("fig7_nnp_{dataset}.csv"))?;
    }
    Ok(())
}
