//! Figure 6, row 1 — execution time of the minimisation vs dataset size
//! (log-log in the paper) for MNIST, WikiWord and Word2Vec, across
//! engines: exact t-SNE, BH-SNE θ=0.1/0.5, t-SNE-CUDA (simulated — the
//! CPU-measured BH time plus the calibrated GPU model), and the
//! field-based engines (fieldcpu + gpgpu when artifacts exist).
//!
//! Expected *shape* (what we reproduce): exact is quadratic and hopeless
//! beyond ~5k; BH is N log N; field-based is linear and overtakes BH by a
//! growing factor.
//!
//!     cargo bench --bench fig6_time            # full sweep
//!     cargo bench --bench fig6_time -- --quick # CI-scale sweep

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::{self, tsnecuda, OptParams};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::bench::{measure_once, quick_mode, Report};
use gpgpu_sne::util::timer::fmt_secs;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let ns: Vec<usize> =
        if quick { vec![500, 1000, 2000] } else { vec![1000, 2000, 5000, 10_000] };
    let iters = if quick { 100 } else { 150 };
    // The paper runs 1000 iterations; we run fewer and report measured
    // time plus the per-1000-iterations extrapolation (time is linear in
    // iterations for every engine — each iteration repeats the same work).
    let scale_to_1000 = 1000.0 / iters as f64;

    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    if rt.is_none() {
        eprintln!("note: no artifacts — gpgpu column skipped");
    }
    println!("fig6 row 1: minimisation time, {iters} iters (reported x{scale_to_1000:.0} = 1000-iter equivalent)");

    for dataset in ["mnist", "wikiword", "word2vec"] {
        let mut report = Report::new(
            &format!("Fig6 time — {dataset} (1000-iter equivalent)"),
            &["exact", "bh-0.1", "bh-0.5", "tsne-cuda-0.5*", "fieldcpu", "fieldfft", "gpgpu"],
        );
        for &n in &ns {
            let ds = gpgpu_sne::data::by_name(dataset, n, 3)?;
            let knn = compute_knn(&ds, KnnMethod::KdForest, 90.min(n / 2), 3);
            let p = perplexity::joint_p(&knn, 30.0);
            let params = OptParams { iters, exaggeration_iters: iters / 4, ..Default::default() };

            let mut cells = vec![format!("{n}")];
            // exact only at small N (quadratic blow-up is itself the datum).
            let exact_cap = if quick { 1000 } else { 2000 };
            let mut bh05_time = None;
            for name in ["exact", "bh-0.1", "bh-0.5"] {
                if name == "exact" && n > exact_cap {
                    cells.push("—".into());
                    continue;
                }
                let mut e = embed::by_name(name, None)?;
                let secs = measure_once(|| {
                    let _ = e.run(&p, &params, None).unwrap();
                }) * scale_to_1000;
                if name == "bh-0.5" {
                    bh05_time = Some(secs);
                }
                cells.push(fmt_secs(secs));
            }
            // t-SNE-CUDA: modelled from the measured BH θ=0.5 time.
            let cuda = tsnecuda::TsneCudaSim::modelled_time(bh05_time.unwrap());
            cells.push(format!("{}*", fmt_secs(cuda)));
            for (name, runtime) in [("fieldcpu", None), ("fieldfft", None), ("gpgpu", rt.clone())] {
                let over_capacity = name == "gpgpu"
                    && runtime.as_ref().map(|r| n > r.manifest.max_bucket()).unwrap_or(true);
                if over_capacity || (name == "gpgpu" && runtime.is_none()) {
                    cells.push("—".into());
                    continue;
                }
                let mut e = embed::by_name(name, runtime)?;
                let secs = measure_once(|| {
                    let _ = e.run(&p, &params, None).unwrap();
                }) * scale_to_1000;
                cells.push(fmt_secs(secs));
            }
            let row_name = cells.remove(0);
            report.row(&row_name, cells);
        }
        report.print();
        report.write_csv(&format!("fig6_time_{dataset}.csv"))?;
    }
    println!("* t-SNE-CUDA time is the calibrated GPU model (DESIGN.md §7), not a measurement.");
    Ok(())
}
