//! Table 1 — the evaluation datasets. Regenerates the paper's inventory
//! (name, N, dims) and adds the measured statistics of our substitutes
//! (DESIGN.md §7): class counts, sparsity, norms, generation speed —
//! making the substitution auditable.
//!
//!     cargo bench --bench table1_datasets [-- --quick]

use gpgpu_sne::data;
use gpgpu_sne::util::bench::{measure_once, quick_mode, Report};
use gpgpu_sne::util::timer::fmt_secs;

fn main() -> anyhow::Result<()> {
    let sample_n = if quick_mode() { 1000 } else { 5000 };
    let mut report = Report::new(
        &format!("Table 1 — datasets (paper scale; stats from n={sample_n} sample)"),
        &["paper N", "dims", "classes", "sparsity", "mean ‖x‖", "gen time"],
    );
    for (name, paper_n, dims) in data::TABLE1 {
        let mut ds = None;
        let secs = measure_once(|| {
            ds = Some(data::by_name(name, sample_n, 1).unwrap());
        });
        let ds = ds.unwrap();
        assert_eq!(ds.d, *dims);
        let mut classes = std::collections::HashSet::new();
        for &l in &ds.labels {
            classes.insert(l);
        }
        let zeros = ds.x.iter().filter(|&&v| v == 0.0).count() as f64 / ds.x.len() as f64;
        let mean_norm: f64 = (0..ds.n)
            .map(|i| ds.row(i).iter().map(|&v| (v * v) as f64).sum::<f64>().sqrt())
            .sum::<f64>()
            / ds.n as f64;
        report.row(
            name,
            vec![
                format!("{paper_n}"),
                format!("{dims}"),
                format!("{}", classes.len()),
                format!("{:.0}%", zeros * 100.0),
                format!("{mean_norm:.2}"),
                fmt_secs(secs),
            ],
        );
    }
    report.print();
    report.write_csv("table1_datasets.csv")?;
    println!("Substitution rationale per dataset: DESIGN.md §7.");
    Ok(())
}
