//! Micro-benchmarks of the hot paths (EXPERIMENTS.md §Perf): field
//! evaluation (gather mirror of the L1 kernel vs the FFT backend, by grid
//! and N), the device step (by grid, measuring the full PJRT execute
//! round-trip and its host-boundary overhead), the repulsion baselines,
//! attractive pass, and the kNN structures.
//!
//! Besides the human-readable tables/CSVs this emits `BENCH_micro.json`
//! (at the *workspace* root, where it is committed): per-engine ns/iter
//! at fixed (N, G), the field-stage head-to-head at N=50 000, G=256, the
//! FFT-core complex-vs-real pipeline ratio, the similarities section
//! (blocked vs scalar brute kNN at N=10k/D=128, fused vs reference P
//! build), the observability section (instrumentation primitives + the
//! <1% session-step overhead gate), the fault-injection section
//! (disabled `fire()` pinned under 1 ns/check), the simd section
//! (per-kernel scalar-vs-dispatched-tier timings for the six ported
//! hot loops plus the forced-scalar fieldfft iteration), and the
//! cluster section (HRW placement decision cost by fleet size, pinned
//! under 1 µs/lookup), so the perf trajectory is machine-trackable
//! across PRs.
//!
//!     cargo bench --bench micro_hotpath [-- --quick]

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::bh::BhRepulsion;
use gpgpu_sne::embed::common::Repulsion;
use gpgpu_sne::embed::exact::ExactRepulsion;
use gpgpu_sne::embed::fieldcpu::{compute_fields, grid_placement, FieldRepulsion};
use gpgpu_sne::field::conv::FftBackend;
use gpgpu_sne::field::{FieldBackend, Placement};
use gpgpu_sne::hd::{bruteforce, kdforest, perplexity, vptree, Dataset};
use gpgpu_sne::runtime::{self, Runtime, StepState};
use gpgpu_sne::util::bench::{measure, quick_mode, Report};
use gpgpu_sne::util::json::Json;
use gpgpu_sne::util::rng::Rng;

fn random_points(n: usize, seed: u64, spread: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let (warmup, iters) = if quick { (1, 3) } else { (2, 7) };
    let mut json_sections: Vec<(&str, Json)> = vec![
        ("bench", Json::Str("micro_hotpath".into())),
        ("quick", Json::Bool(quick)),
    ];

    // --- Field evaluation: grid × N scaling. Gather is the paper's
    // O(N·G²) compute-shader mirror; fft is the O(N + G² log G) backend.
    let mut rep = Report::new(
        "fields eval (gather mirror of the L1 kernel vs FFT backend)",
        &["gather", "fft", "speedup"],
    );
    let mut fft_backend = FftBackend::new();
    for &(n, grid) in &[(1000usize, 64usize), (1000, 128), (1000, 256), (4000, 128), (16_000, 128)]
    {
        let y = random_points(n, 1, 10.0);
        let (origin, pixel) = grid_placement([-30.0, -30.0, 30.0, 30.0], grid);
        let st = measure(warmup, iters, || {
            let _ = compute_fields(&y, origin, pixel, grid);
        });
        let placement = Placement { origin, pixel };
        let stf = measure(warmup, iters, || {
            let _ = fft_backend.compute(&y, placement, grid);
        });
        rep.row(
            &format!("n={n} G={grid}"),
            vec![
                format!("{:.2}ms", st.median() * 1e3),
                format!("{:.2}ms", stf.median() * 1e3),
                format!("{:.1}x", st.median() / stf.median()),
            ],
        );
    }
    rep.print();
    rep.write_csv("micro_fields.csv")?;

    // --- Field stage head-to-head at production scale (the acceptance
    // point for the fieldfft engine): N=50 000, G=256.
    {
        let n = 50_000usize;
        let grid = 256usize;
        let y = random_points(n, 9, 15.0);
        let (origin, pixel) = grid_placement([-60.0, -60.0, 60.0, 60.0], grid);
        let placement = Placement { origin, pixel };
        let (w, it) = if quick { (0, 1) } else { (1, 3) };
        let gather_t = measure(w, it, || {
            let _ = compute_fields(&y, origin, pixel, grid);
        })
        .median();
        let mut backend = FftBackend::new();
        // One warmup always: the first call builds the kernel spectra that
        // every later iteration reuses (that is the steady-state cost).
        let fft_t = measure(w.max(1), it.max(2), || {
            let _ = backend.compute(&y, placement, grid);
        })
        .median();
        let speedup = gather_t / fft_t;
        let mut rep = Report::new(
            &format!("field stage @ N={n}, G={grid} (steady state)"),
            &["median", "per-point", "vs gather"],
        );
        rep.row(
            "fieldcpu (gather)",
            vec![
                format!("{:.1}ms", gather_t * 1e3),
                format!("{:.2}µs", gather_t * 1e6 / n as f64),
                "1.0x".into(),
            ],
        );
        rep.row(
            "fieldfft (splat+FFT)",
            vec![
                format!("{:.1}ms", fft_t * 1e3),
                format!("{:.2}µs", fft_t * 1e6 / n as f64),
                format!("{speedup:.1}x"),
            ],
        );
        rep.print();
        rep.write_csv("micro_field_stage.csv")?;
        json_sections.push((
            "field_stage",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("grid", Json::Num(grid as f64)),
                (
                    "engines",
                    Json::Arr(vec![
                        Json::obj(vec![
                            ("name", Json::Str("fieldcpu".into())),
                            ("ns_per_iter", Json::Num(gather_t * 1e9)),
                        ]),
                        Json::obj(vec![
                            ("name", Json::Str("fieldfft".into())),
                            ("ns_per_iter", Json::Num(fft_t * 1e9)),
                        ]),
                    ]),
                ),
                ("speedup_fieldfft_vs_fieldcpu", Json::Num(speedup)),
            ]),
        ));
    }

    // --- FFT core: full-complex vs real-packed (r2c/c2r) 2-D pipeline
    // at the production transform size (M=2048 is what G=256, s=2 pads
    // to). Roundtrip = forward + inverse, the per-channel unit of work.
    {
        use gpgpu_sne::field::fft::{fft2d, half_width, irfft2d, rfft2d, Fft};
        let m = if quick { 512usize } else { 2048 };
        let hw = half_width(m);
        let plan = Fft::new(m);
        let base = random_points(m * m / 2, 5, 1.0); // m·m values
        let mut cre = vec![0.0f32; m * m];
        let mut cim = vec![0.0f32; m * m];
        let complex_t = measure(warmup, iters, || {
            cre.copy_from_slice(&base);
            cim.iter_mut().for_each(|v| *v = 0.0);
            fft2d(&plan, &mut cre, &mut cim, false);
            fft2d(&plan, &mut cre, &mut cim, true);
        })
        .median();
        let mut plane = vec![0.0f32; m * m];
        let mut sre = vec![0.0f32; hw * m];
        let mut sim = vec![0.0f32; hw * m];
        let mut tre = vec![0.0f32; m * hw];
        let mut tim = vec![0.0f32; m * hw];
        let inv_m2 = 1.0 / (m * m) as f32;
        let real_t = measure(warmup, iters, || {
            plane.copy_from_slice(&base);
            rfft2d(&plan, &mut plane, &mut sre, &mut sim, &mut tre, &mut tim);
            irfft2d(&plan, &mut sre, &mut sim, &mut plane, &mut tre, &mut tim, inv_m2);
        })
        .median();
        let speedup = complex_t / real_t;
        let mut rep = Report::new(&format!("fft core roundtrip @ M={m}"), &["median", "speedup"]);
        rep.row("complex 2-D", vec![format!("{:.2}ms", complex_t * 1e3), "1.0x".into()]);
        rep.row("real r2c/c2r", vec![format!("{:.2}ms", real_t * 1e3), format!("{speedup:.2}x")]);
        rep.print();
        rep.write_csv("micro_fft_core.csv")?;
        json_sections.push((
            "fft_core",
            Json::obj(vec![
                ("m", Json::Num(m as f64)),
                ("complex_roundtrip_ns", Json::Num(complex_t * 1e9)),
                ("real_roundtrip_ns", Json::Num(real_t * 1e9)),
                ("speedup_real_vs_complex", Json::Num(speedup)),
            ]),
        ));
    }

    // --- Repulsion approaches at fixed n (per-engine ns/iter).
    let n = if quick { 2000 } else { 8000 };
    let grid_fixed = 256usize;
    let y = random_points(n, 2, 20.0);
    let mut num = vec![0.0f32; 2 * n];
    let mut rep = Report::new(&format!("repulsion variants (n={n})"), &["median", "vs exact"]);
    let mut engine_rows: Vec<Json> = Vec::new();
    let exact_t = measure(warmup, iters, || {
        ExactRepulsion.compute(&y, &mut num);
    })
    .median();
    rep.row("exact O(N²)", vec![format!("{:.1}ms", exact_t * 1e3), "1.0x".into()]);
    engine_rows.push(Json::obj(vec![
        ("name", Json::Str("exact".into())),
        ("ns_per_iter", Json::Num(exact_t * 1e9)),
    ]));
    for theta in [0.1f32, 0.5] {
        let mut bhr = BhRepulsion::new(theta);
        let t = measure(warmup, iters, || {
            bhr.compute(&y, &mut num);
        })
        .median();
        rep.row(
            &format!("BH θ={theta}"),
            vec![format!("{:.1}ms", t * 1e3), format!("{:.1}x", exact_t / t)],
        );
        engine_rows.push(Json::obj(vec![
            ("name", Json::Str(format!("bh-{theta}"))),
            ("ns_per_iter", Json::Num(t * 1e9)),
        ]));
    }
    for (label, fft) in [("fieldcpu", false), ("fieldfft", true)] {
        let mut fr = if fft {
            FieldRepulsion {
                min_grid: grid_fixed,
                max_grid: grid_fixed,
                ..FieldRepulsion::with_backend(Box::new(FftBackend::new()))
            }
        } else {
            FieldRepulsion { min_grid: grid_fixed, max_grid: grid_fixed, ..Default::default() }
        };
        let t = measure(warmup, iters, || {
            fr.compute(&y, &mut num);
        })
        .median();
        rep.row(
            &format!("{label} G={grid_fixed}"),
            vec![format!("{:.1}ms", t * 1e3), format!("{:.1}x", exact_t / t)],
        );
        engine_rows.push(Json::obj(vec![
            ("name", Json::Str(label.into())),
            ("ns_per_iter", Json::Num(t * 1e9)),
        ]));
    }
    rep.print();
    rep.write_csv("micro_repulsion.csv")?;
    json_sections.push((
        "repulsion",
        Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("grid", Json::Num(grid_fixed as f64)),
            ("engines", Json::Arr(engine_rows)),
        ]),
    ));

    // --- Device step: per-grid execute cost + host-boundary overhead.
    if let Some(dir) = runtime::locate_artifacts() {
        let rt = Arc::new(Runtime::new(&dir)?);
        let mut rep = Report::new("device step (PJRT execute round-trip)", &["median", "per-point"]);
        let buckets: Vec<usize> = {
            let mut b: Vec<usize> = rt.manifest.steps().map(|a| a.n).collect();
            b.sort_unstable();
            b.dedup();
            b
        };
        for &npad in &buckets {
            for grid in rt.manifest.grids_for(npad) {
                let exe = rt.step_executable(npad, grid)?;
                let k = exe.spec.k;
                let mut mask = vec![0.0f32; npad];
                let n_real = npad * 3 / 4;
                mask[..n_real].fill(1.0);
                let idx = vec![0i32; npad * k];
                let mut pv = vec![0.0f32; npad * k];
                for i in 0..n_real {
                    pv[i * k] = 1.0 / n_real as f32;
                }
                let statics = rt.upload_static(&mask, &idx, &pv, k)?;
                let y0 = random_points(npad, 3, 5.0);
                let mut state = StepState::new(y0, &mask);
                let st = measure(warmup, iters, || {
                    let _ = rt.run_step(&exe, &mut state, &statics, 200.0, 0.5, 1.0).unwrap();
                });
                rep.row(
                    &format!("n={npad} G={grid}"),
                    vec![
                        format!("{:.2}ms", st.median() * 1e3),
                        format!("{:.2}µs", st.median() * 1e6 / n_real as f64),
                    ],
                );
            }
        }
        rep.print();
        rep.write_csv("micro_device_step.csv")?;
    } else {
        eprintln!("note: no artifacts — device-step section skipped");
    }

    // --- kNN structures.
    let kn = if quick { 2000 } else { 10_000 };
    let ds = gpgpu_sne::data::by_name("mnist", kn, 4)?;
    let mut rep = Report::new(&format!("kNN (n={kn}, d=784, k=90)"), &["median", "recall"]);
    let brute_t = measure(0, 1, || {
        let _ = compute_knn(&ds, KnnMethod::Brute, 90, 4);
    })
    .median();
    let exact = compute_knn(&ds, KnnMethod::Brute, 90, 4);
    rep.row("brute", vec![format!("{:.2}s", brute_t), "1.000".into()]);
    let vp_t = measure(0, 1, || {
        let _ = vptree::VpTree::build(&ds, 4).knn(90);
    })
    .median();
    let vp = vptree::VpTree::build(&ds, 4).knn(90);
    rep.row("vptree", vec![format!("{:.2}s", vp_t), format!("{:.3}", vp.recall_against(&exact))]);
    let kd_t = measure(0, 1, || {
        let _ = kdforest::KdForest::build(&ds, kdforest::ForestParams::default(), 4).knn(90);
    })
    .median();
    let kd = kdforest::KdForest::build(&ds, kdforest::ForestParams::default(), 4).knn(90);
    rep.row("kdforest", vec![format!("{:.2}s", kd_t), format!("{:.3}", kd.recall_against(&exact))]);
    rep.print();
    rep.write_csv("micro_knn.csv")?;

    // --- Similarities: blocked panel kernel vs the scalar per-pair scan
    // (brute kNN at the acceptance point N=10k, D=128; quick mode scales
    // N down like every other section) and the fused one-pass P build vs
    // the seed's transpose-and-merge reference.
    {
        let sn = if quick { 2000usize } else { 10_000 };
        let sd = 128usize;
        let sk = 90usize;
        let mut rng = Rng::new(12);
        let x: Vec<f32> = (0..sn * sd).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let ds = Dataset::new("similarities-bench", sn, sd, x, vec![]);
        let it = if quick { 1 } else { 3 };
        // The oracle graphs double as warmup for the timed runs below.
        let g_scalar = bruteforce::knn_scalar_reference(&ds, sk);
        let g = bruteforce::knn(&ds, sk);
        let recall = g.recall_against(&g_scalar);
        let scalar_t = measure(0, it, || {
            let _ = bruteforce::knn_scalar_reference(&ds, sk);
        })
        .median();
        let blocked_t = measure(0, it, || {
            let _ = bruteforce::knn(&ds, sk);
        })
        .median();
        let knn_speedup = scalar_t / blocked_t;
        let p_ref_t = measure(0, it.max(2), || {
            let _ = perplexity::joint_p_reference(&g, 30.0);
        })
        .median();
        let p_fused_t = measure(0, it.max(2), || {
            let _ = perplexity::joint_p(&g, 30.0);
        })
        .median();
        let p_speedup = p_ref_t / p_fused_t;
        let mut rep = Report::new(
            &format!("similarities @ N={sn}, D={sd}, k={sk}"),
            &["median", "speedup", "recall"],
        );
        rep.row(
            "brute kNN scalar (seed)",
            vec![format!("{:.2}s", scalar_t), "1.0x".into(), "1.000".into()],
        );
        rep.row(
            "brute kNN blocked",
            vec![
                format!("{:.2}s", blocked_t),
                format!("{knn_speedup:.1}x"),
                format!("{recall:.3}"),
            ],
        );
        rep.row(
            "P build reference (seed)",
            vec![format!("{:.1}ms", p_ref_t * 1e3), "1.0x".into(), "-".into()],
        );
        rep.row(
            "P build fused",
            vec![format!("{:.1}ms", p_fused_t * 1e3), format!("{p_speedup:.1}x"), "-".into()],
        );
        rep.print();
        rep.write_csv("micro_similarities.csv")?;
        json_sections.push((
            "similarities",
            Json::obj(vec![
                ("n", Json::Num(sn as f64)),
                ("d", Json::Num(sd as f64)),
                ("k", Json::Num(sk as f64)),
                ("knn_scalar_ns", Json::Num(scalar_t * 1e9)),
                ("knn_blocked_ns", Json::Num(blocked_t * 1e9)),
                ("speedup_blocked_vs_scalar", Json::Num(knn_speedup)),
                ("recall_blocked_vs_scalar", Json::Num(recall)),
                ("p_build_reference_ns", Json::Num(p_ref_t * 1e9)),
                ("p_build_fused_ns", Json::Num(p_fused_t * 1e9)),
                ("speedup_fused_vs_reference", Json::Num(p_speedup)),
            ]),
        ));
    }

    // --- Session-API dispatch overhead: the stepwise EmbeddingSession
    // (one virtual `step()` per iteration, always-on stats/bbox) vs the
    // old fused loop shape (repulsion + attractive + fused_step inlined,
    // headless). Same engine math on both sides; the target is <1%
    // overhead at N=10k — the price of pause/resume/checkpoint being
    // first-class.
    {
        use gpgpu_sne::embed::common::GdState;
        use gpgpu_sne::embed::Engine;
        use gpgpu_sne::hd::sparse::Csr;
        use gpgpu_sne::hd::SparseP;

        let sn = if quick { 2000usize } else { 10_000 };
        let sk = 8usize;
        let mut col = Vec::with_capacity(sn * sk);
        let mut val = Vec::with_capacity(sn * sk);
        for i in 0..sn {
            for j in 1..=sk {
                col.push(((i + j) % sn) as u32);
                val.push(1.0 / (sn * sk) as f32);
            }
        }
        let p = SparseP {
            csr: Csr::from_rows(sn, sn, sk, col, val),
            perplexity: sk as f32,
        };
        let bench_iters = 30usize;
        let opt = gpgpu_sne::embed::OptParams {
            iters: bench_iters,
            exaggeration_iters: 10,
            seed: 3,
            ..Default::default()
        };
        let it = if quick { 2 } else { 4 };

        // Old fused-loop shape, reconstructed from the same public parts
        // the sessions use (this IS what run_gd_loop compiled to before
        // the session API, headless variant: no bbox, no stats).
        let fused_t = measure(1, it, || {
            let mut state = GdState::init(sn, opt.seed, opt.init_std);
            let mut rep = BhRepulsion::new(0.5);
            let mut attr = vec![0.0f32; 2 * sn];
            let mut repnum = vec![0.0f32; 2 * sn];
            for iter in 0..opt.iters {
                let ex = opt.exaggeration_at(iter);
                let _ = gpgpu_sne::embed::attractive_forces(&p, &state.y, &mut attr);
                let z = rep.compute(&state.y, &mut repnum).max(1e-12);
                let inv_z = (1.0 / z) as f32;
                state.fused_step(&attr, &repnum, ex, inv_z, opt.eta, opt.momentum_at(iter), false);
            }
        })
        .median();
        let session_t = measure(1, it, || {
            let mut engine = gpgpu_sne::embed::by_name("bh-0.5", None).unwrap();
            let mut session = engine.begin(Arc::new(p.clone()), &opt).unwrap();
            while !session.is_done() {
                let _ = session.step().unwrap();
            }
        })
        .median();
        let fused_ns = fused_t * 1e9 / bench_iters as f64;
        let session_ns = session_t * 1e9 / bench_iters as f64;
        let overhead_pct = (session_ns - fused_ns) / fused_ns * 100.0;
        let mut rep = Report::new(
            &format!("session-API step dispatch @ N={sn} (bh-0.5, {bench_iters} iters)"),
            &["ns/iter", "overhead"],
        );
        rep.row("fused loop (pre-session shape)", vec![format!("{fused_ns:.0}"), "-".into()]);
        rep.row(
            "EmbeddingSession::step loop",
            vec![format!("{session_ns:.0}"), format!("{overhead_pct:+.2}%")],
        );
        rep.print();
        rep.write_csv("micro_session_step.csv")?;
        json_sections.push((
            "session_step",
            Json::obj(vec![
                ("n", Json::Num(sn as f64)),
                ("engine", Json::Str("bh-0.5".into())),
                ("iters", Json::Num(bench_iters as f64)),
                ("fused_loop_ns_per_iter", Json::Num(fused_ns)),
                ("session_ns_per_iter", Json::Num(session_ns)),
                ("overhead_pct", Json::Num(overhead_pct)),
            ]),
        ));
    }

    // --- Observability overhead (ARCHITECTURE.md §Observability): the
    // primitive costs (counter add, histogram record, span begin+end
    // into the per-thread trace ring), then the acceptance point — the
    // same session step loop as above with hot-path instrumentation
    // (span emission + per-phase engine timing) off vs on. Budget: <1%
    // per step, with a 5 µs absolute floor so timer noise on tiny
    // quick-mode steps cannot fail the gate.
    {
        use gpgpu_sne::hd::sparse::Csr;
        use gpgpu_sne::hd::SparseP;
        use gpgpu_sne::obs;

        let it = if quick { 2 } else { 4 };
        let ops = if quick { 200_000u64 } else { 1_000_000 };
        let reg = obs::Registry::new();
        let c = reg.counter("bench.events");
        let h = reg.histogram("bench.lat_ns");
        let counter_t = measure(1, it.max(3), || {
            for _ in 0..ops {
                c.inc();
            }
        })
        .min();
        let counter_ns = counter_t * 1e9 / ops as f64;
        let hist_t = measure(1, it.max(3), || {
            for i in 0..ops {
                h.record(i);
            }
        })
        .min();
        let hist_ns = hist_t * 1e9 / ops as f64;
        // A job id no real job can collide with, so `trace` snapshots in
        // concurrent use of the same process stay clean.
        let job = 0xb0b0_0b50u64;
        let spans = ops / 8;
        let span_t = measure(1, it.max(3), || {
            for i in 0..spans {
                obs::span_begin(obs::Span::EngineStep, job, i);
                obs::span_end(obs::Span::EngineStep, job, i);
            }
        })
        .min();
        let span_ns = span_t * 1e9 / spans as f64;

        let sn = if quick { 2000usize } else { 10_000 };
        let sk = 8usize;
        let mut col = Vec::with_capacity(sn * sk);
        let mut val = Vec::with_capacity(sn * sk);
        for i in 0..sn {
            for j in 1..=sk {
                col.push(((i + j) % sn) as u32);
                val.push(1.0 / (sn * sk) as f32);
            }
        }
        let p = SparseP { csr: Csr::from_rows(sn, sn, sk, col, val), perplexity: sk as f32 };
        let bench_iters = 30usize;
        let opt = gpgpu_sne::embed::OptParams {
            iters: bench_iters,
            exaggeration_iters: 10,
            seed: 3,
            ..Default::default()
        };
        // Identical code shape both times — the only delta is the obs
        // switch, exactly what `serve` toggles. The span per step mirrors
        // what the scheduler emits around session.step().
        let run = |on: bool| {
            obs::set_enabled(on);
            let st = measure(1, it.max(3), || {
                let mut engine = gpgpu_sne::embed::by_name("bh-0.5", None).unwrap();
                let mut session = engine.begin(Arc::new(p.clone()), &opt).unwrap();
                let mut i = 0u64;
                while !session.is_done() {
                    let _step = obs::span(obs::Span::EngineStep, job, i);
                    let _ = session.step().unwrap();
                    i += 1;
                }
            })
            .min();
            st * 1e9 / bench_iters as f64
        };
        let off_ns = run(false);
        let on_ns = run(true);
        obs::set_enabled(true);
        let overhead_pct = (on_ns - off_ns) / off_ns * 100.0;
        let mut rep = Report::new(
            &format!("observability overhead @ N={sn} (bh-0.5, {bench_iters} iters)"),
            &["cost", "overhead"],
        );
        rep.row("counter.inc", vec![format!("{counter_ns:.1}ns"), "-".into()]);
        rep.row("histogram.record", vec![format!("{hist_ns:.1}ns"), "-".into()]);
        rep.row("span begin+end", vec![format!("{span_ns:.0}ns"), "-".into()]);
        rep.row("session step, obs off", vec![format!("{off_ns:.0}ns/iter"), "-".into()]);
        rep.row(
            "session step, obs on",
            vec![format!("{on_ns:.0}ns/iter"), format!("{overhead_pct:+.2}%")],
        );
        rep.print();
        rep.write_csv("micro_obs.csv")?;
        assert!(
            overhead_pct < 1.0 || (on_ns - off_ns) < 5_000.0,
            "instrumentation overhead {overhead_pct:.2}% ({:.0}ns/iter) blows the <1% budget",
            on_ns - off_ns
        );
        json_sections.push((
            "obs",
            Json::obj(vec![
                ("n", Json::Num(sn as f64)),
                ("engine", Json::Str("bh-0.5".into())),
                ("iters", Json::Num(bench_iters as f64)),
                ("counter_inc_ns", Json::Num(counter_ns)),
                ("histogram_record_ns", Json::Num(hist_ns)),
                ("span_pair_ns", Json::Num(span_ns)),
                ("step_obs_off_ns_per_iter", Json::Num(off_ns)),
                ("step_obs_on_ns_per_iter", Json::Num(on_ns)),
                ("overhead_pct", Json::Num(overhead_pct)),
            ]),
        ));
    }

    // --- Fault-injection check cost (ARCHITECTURE.md §Failure domains):
    // `faultinject::fire` sits on the engine step, store write, and
    // connection paths, so its disabled fast path — one relaxed atomic
    // load — must stay under 1 ns/check. Also reported (informational):
    // the enabled-but-unarmed slow path a chaos run pays on points it
    // did not arm.
    {
        use gpgpu_sne::coordinator::faultinject;

        let it = if quick { 3 } else { 5 };
        let ops = if quick { 2_000_000u64 } else { 10_000_000 };
        faultinject::disarm_all();
        let disabled_t = measure(1, it, || {
            let mut fired = 0u64;
            for _ in 0..ops {
                fired += faultinject::fire(faultinject::TEST_POINT) as u64;
            }
            // The registry is process-global state the optimiser cannot
            // see through, but keep the result live regardless.
            assert_eq!(std::hint::black_box(fired), 0);
        })
        .min();
        let disabled_ns = disabled_t * 1e9 / ops as f64;
        // Arm an unrelated point: the probed point takes the enabled
        // slow path (registry lookup) but never fires.
        let _armed = faultinject::guard("net.stall=once").expect("valid spec");
        let unarmed_ops = ops / 10;
        let unarmed_t = measure(1, it, || {
            let mut fired = 0u64;
            for _ in 0..unarmed_ops {
                fired += faultinject::fire(faultinject::TEST_POINT) as u64;
            }
            assert_eq!(std::hint::black_box(fired), 0);
        })
        .min();
        let unarmed_ns = unarmed_t * 1e9 / unarmed_ops as f64;
        drop(_armed);
        let mut rep = Report::new("fault-injection check cost", &["ns/check"]);
        rep.row("fire(), disabled (production)", vec![format!("{disabled_ns:.3}")]);
        rep.row("fire(), enabled + unarmed point", vec![format!("{unarmed_ns:.2}")]);
        rep.print();
        rep.write_csv("micro_faultinject.csv")?;
        assert!(
            disabled_ns < 1.0,
            "disabled fault check costs {disabled_ns:.3}ns — the zero-overhead contract \
             (<1ns/check) is broken"
        );
        json_sections.push((
            "faultinject",
            Json::obj(vec![
                ("checks", Json::Num(ops as f64)),
                ("disabled_ns_per_check", Json::Num(disabled_ns)),
                ("enabled_unarmed_ns_per_check", Json::Num(unarmed_ns)),
                ("budget_ns", Json::Num(1.0)),
            ]),
        ));
    }

    // --- SIMD dispatch (ARCHITECTURE.md §SIMD): the six ported hot
    // loops, scalar tier vs the resolved tier — kernel-level through
    // `Kernels::for_tier` (no global flip) — plus the end-to-end
    // fieldfft iteration under forced-scalar vs auto dispatch
    // (`set_tier` is process-global; this bench is single-threaded
    // between measures, so the flip is safe).
    {
        use gpgpu_sne::util::simd::{self, GdArgs, Kernels, Tier};

        let active = simd::active_tier();
        let tiers = [Kernels::for_tier(Tier::Scalar), Kernels::for_tier(active)];
        let it = if quick { 3 } else { 6 };
        // (name, scalar_ns, simd_ns) per kernel workload.
        let mut entries: Vec<(&str, f64, f64)> = Vec::new();

        // Blocked-kNN panel kernels at the production depth D=128: the
        // quad-row dot4 `scan_candidates` runs, and the single-row dot.
        {
            let d = 128usize;
            let rows = 512usize;
            let mut rng = Rng::new(41);
            let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let x: Vec<f32> = (0..rows * d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut quad = [0.0f64; 2];
            let mut single = [0.0f64; 2];
            for (ti, k) in tiers.iter().enumerate() {
                quad[ti] = measure(1, it, || {
                    let mut s = 0.0f32;
                    for r in (0..rows).step_by(4) {
                        let o = r * d;
                        let v = (k.dot4)(
                            &q,
                            &x[o..o + d],
                            &x[o + d..o + 2 * d],
                            &x[o + 2 * d..o + 3 * d],
                            &x[o + 3 * d..o + 4 * d],
                        );
                        s += (v[0] + v[1]) + (v[2] + v[3]);
                    }
                    std::hint::black_box(s);
                })
                .min()
                    * 1e9
                    / rows as f64;
                single[ti] = measure(1, it, || {
                    let mut s = 0.0f32;
                    for r in 0..rows {
                        s += (k.dot)(&q, &x[r * d..(r + 1) * d]);
                    }
                    std::hint::black_box(s);
                })
                .min()
                    * 1e9
                    / rows as f64;
            }
            entries.push(("knn_panel_dot4", quad[0], quad[1]));
            entries.push(("knn_dot", single[0], single[1]));
        }

        // One radix-2 stage group at the production FFT width.
        {
            let half = 2048usize;
            let mut rng = Rng::new(42);
            let mut ra: Vec<f32> = (0..half).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut ia: Vec<f32> = (0..half).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut rb = ra.clone();
            let mut ib = ia.clone();
            let wr: Vec<f32> = (0..half).map(|k| (k as f32 / half as f32).cos()).collect();
            let wi: Vec<f32> = (0..half).map(|k| -(k as f32 / half as f32).sin()).collect();
            let mut times = [0.0f64; 2];
            for (ti, k) in tiers.iter().enumerate() {
                times[ti] = measure(1, it, || {
                    for inverse in [false, true] {
                        (k.butterflies)(&mut ra, &mut ia, &mut rb, &mut ib, &wr, &wi, inverse);
                    }
                })
                .min()
                    * 1e9
                    / 2.0;
            }
            entries.push(("fft_butterfly", times[0], times[1]));
        }

        // Cubic-Lagrange 4×4 deposit (one splat per point).
        {
            let grid = 256usize;
            let points = 4096usize;
            let mut out = vec![0.0f32; grid * grid];
            let mut rng = Rng::new(43);
            let bases: Vec<usize> = (0..points)
                .map(|_| {
                    let r = (rng.gauss_f32(0.0, 1.0).abs() * 97.0) as usize % (grid - 4);
                    let c = (rng.gauss_f32(0.0, 1.0).abs() * 89.0) as usize % (grid - 4);
                    r * grid + c
                })
                .collect();
            let wu = [0.1f32, 0.4, 0.4, 0.1];
            let wv = [0.2f32, 0.3, 0.3, 0.2];
            let mut times = [0.0f64; 2];
            for (ti, k) in tiers.iter().enumerate() {
                times[ti] = measure(1, it, || {
                    for &b in &bases {
                        (k.deposit4x4)(&mut out, b, grid, &wu, &wv);
                    }
                })
                .min()
                    * 1e9
                    / points as f64;
            }
            entries.push(("splat_deposit", times[0], times[1]));
        }

        // Cauchy field-row accumulation (one point across a G=256 row).
        {
            let grid = 256usize;
            let points = 512usize;
            let px: Vec<f32> = (0..grid).map(|c| c as f32 * 0.1).collect();
            let mut s = vec![0.0f32; grid];
            let mut vx = vec![0.0f32; grid];
            let mut vy = vec![0.0f32; grid];
            let mut times = [0.0f64; 2];
            for (ti, k) in tiers.iter().enumerate() {
                times[ti] = measure(1, it, || {
                    for i in 0..points {
                        let yx = i as f32 * 0.03;
                        (k.cauchy_row)(&px, 1.5, yx, yx * 0.5, &mut s, &mut vx, &mut vy);
                    }
                })
                .min()
                    * 1e9
                    / points as f64;
            }
            entries.push(("gather_row", times[0], times[1]));
        }

        // Fused GD update over one STEP_CHUNK-sized slab.
        {
            let m = 2 * 2048usize;
            let mut rng = Rng::new(44);
            let mut ygd: Vec<f32> = (0..m).map(|_| rng.gauss_f32(0.0, 5.0)).collect();
            let mut vgd: Vec<f32> = (0..m).map(|_| rng.gauss_f32(0.0, 0.5)).collect();
            let mut ggd = vec![1.0f32; m];
            let attr_gd: Vec<f32> = (0..m).map(|_| rng.gauss_f32(0.0, 0.1)).collect();
            let rep_gd: Vec<f32> = (0..m).map(|_| rng.gauss_f32(0.0, 5.0)).collect();
            let mut times = [0.0f64; 2];
            for (ti, k) in tiers.iter().enumerate() {
                times[ti] = measure(1, it, || {
                    let part = (k.gd_update)(GdArgs {
                        y: &mut ygd,
                        vel: &mut vgd,
                        gains: &mut ggd,
                        attr: &attr_gd,
                        rep: &rep_gd,
                        exaggeration: 4.0,
                        inv_z: 0.25,
                        eta: 200.0,
                        momentum: 0.5,
                        track_bbox: true,
                    });
                    std::hint::black_box(part.sx);
                })
                .min()
                    * 1e9
                    / (m / 2) as f64;
            }
            entries.push(("gd_fused_per_point", times[0], times[1]));
        }

        // Fused three-channel spectral multiply over one par_chunks slab
        // (the ISSUE 9 port: the FFT backend's per-iteration hot pass).
        {
            use gpgpu_sne::util::simd::SpectralArgs;
            let ns = 1usize << 15;
            let mut rng = Rng::new(45);
            let mut gen = |scale: f32| -> Vec<f32> {
                (0..ns).map(|_| rng.gauss_f32(0.0, scale)).collect()
            };
            let (ks_re, ks_im) = (gen(1.0), gen(1.0));
            let (kx_re, kx_im) = (gen(0.5), gen(0.5));
            let (ky_re, ky_im) = (gen(0.5), gen(0.5));
            let mut sre = gen(2.0);
            let mut sim = gen(2.0);
            let mut xre = vec![0.0f32; ns];
            let mut xim = vec![0.0f32; ns];
            let mut yre = vec![0.0f32; ns];
            let mut yim = vec![0.0f32; ns];
            let mut times = [0.0f64; 2];
            for (ti, k) in tiers.iter().enumerate() {
                times[ti] = measure(1, it, || {
                    (k.spectral_mul)(SpectralArgs {
                        sre: &mut sre,
                        sim: &mut sim,
                        xre: &mut xre,
                        xim: &mut xim,
                        yre: &mut yre,
                        yim: &mut yim,
                        ks_re: &ks_re,
                        ks_im: &ks_im,
                        kx_re: &kx_re,
                        kx_im: &kx_im,
                        ky_re: &ky_re,
                        ky_im: &ky_im,
                    });
                    std::hint::black_box(sre[0]);
                })
                .min()
                    * 1e9
                    / ns as f64;
            }
            entries.push(("spectral_mul_per_entry", times[0], times[1]));
        }

        // End-to-end fieldfft iteration: forced-scalar vs auto dispatch
        // (the ISSUE 8 acceptance point for the field stage).
        {
            let nff = if quick { 4000usize } else { 16_000 };
            let grid = 256usize;
            let yff = random_points(nff, 33, 15.0);
            let (origin, pixel) = grid_placement([-60.0, -60.0, 60.0, 60.0], grid);
            let placement = Placement { origin, pixel };
            let mut backend = FftBackend::new();
            simd::set_tier(Some(Tier::Scalar));
            let scalar_t = measure(1, it.max(3), || {
                let _ = backend.compute(&yff, placement, grid);
            })
            .min();
            simd::set_tier(None);
            let auto_t = measure(1, it.max(3), || {
                let _ = backend.compute(&yff, placement, grid);
            })
            .min();
            entries.push(("fieldfft_iter", scalar_t * 1e9, auto_t * 1e9));
        }
        simd::set_tier(None);

        let mut rep = Report::new(
            &format!("simd kernels (tier '{}' vs scalar)", active.name()),
            &["scalar", "simd", "speedup"],
        );
        let mut kernel_rows: Vec<Json> = Vec::new();
        for &(name, scalar_ns, simd_ns) in &entries {
            let speedup = scalar_ns / simd_ns;
            rep.row(
                name,
                vec![
                    format!("{scalar_ns:.1}ns"),
                    format!("{simd_ns:.1}ns"),
                    format!("{speedup:.2}x"),
                ],
            );
            kernel_rows.push(Json::obj(vec![
                ("name", Json::Str(name.into())),
                ("scalar_ns", Json::Num(scalar_ns)),
                ("simd_ns", Json::Num(simd_ns)),
                ("speedup", Json::Num(speedup)),
            ]));
        }
        rep.print();
        rep.write_csv("micro_simd.csv")?;
        json_sections.push((
            "simd",
            Json::obj(vec![
                ("tier", Json::Str(active.name().into())),
                ("detected", Json::Str(simd::detected_tier().name().into())),
                ("kernels", Json::Arr(kernel_rows)),
            ]),
        ));
    }

    // --- Perplexity + attractive pass.
    let p = perplexity::joint_p(&exact, 30.0);
    let y = random_points(kn, 6, 10.0);
    let mut attr = vec![0.0f32; 2 * kn];
    let at = measure(warmup, iters, || {
        let _ = gpgpu_sne::embed::attractive_forces(&p, &y, &mut attr);
    });
    let mut rep = Report::new("sparse passes", &["median"]);
    rep.row("attractive (n·k)", vec![format!("{:.2}ms", at.median() * 1e3)]);
    let pt = measure(0, 1, || {
        let _ = perplexity::joint_p(&exact, 30.0);
    });
    rep.row("perplexity+P build", vec![format!("{:.2}ms", pt.median() * 1e3)]);
    rep.print();
    rep.write_csv("micro_sparse.csv")?;

    // --- Durable store: checkpoint codec encode/decode and similarity-
    // store record write/read throughput (the costs `serve --state-dir`
    // adds to the scheduler's quantum boundary and the prepare stage).
    {
        use gpgpu_sne::coordinator::store::SimStore;
        use gpgpu_sne::coordinator::{GraphKey, SimKey};
        use gpgpu_sne::embed::Checkpoint;

        let cn = if quick { 20_000usize } else { 100_000 };
        let mut rng = Rng::new(31);
        let ck = Checkpoint {
            engine: "bh-0.5".into(),
            iter: 500,
            elapsed_s: 12.5,
            y: (0..2 * cn).map(|_| rng.gauss_f32(0.0, 5.0)).collect(),
            vel: (0..2 * cn).map(|_| rng.gauss_f32(0.0, 0.5)).collect(),
            gains: (0..2 * cn).map(|_| rng.gauss_f32(1.0, 0.1)).collect(),
            grid: None,
        };
        let bytes = ck.to_bytes();
        let mb = bytes.len() as f64 / 1e6;
        let enc_t = measure(1, iters.max(3), || {
            let _ = ck.to_bytes();
        })
        .median();
        let dec_t = measure(1, iters.max(3), || {
            let _ = Checkpoint::from_bytes(&bytes).unwrap();
        })
        .median();

        let dir = std::env::temp_dir().join(format!("gsne-bench-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SimStore::open(&dir)?;
        let gkey = GraphKey {
            fingerprint: 0xbe7c4,
            method: KnnMethod::Brute,
            k: exact.k,
            seed: 4,
        };
        let pkey = SimKey { graph: gkey, perplexity_bits: 30.0f32.to_bits() };
        let graph_mb = (exact.idx.len() * 8) as f64 / 1e6;
        let p_mb = (p.csr.val.len() * 8 + p.csr.row_ptr.len() * 8) as f64 / 1e6;
        let wr_t = measure(1, iters.max(3), || {
            store.store_graph(&gkey, &exact);
            store.store_p(&pkey, &p);
        })
        .median();
        let rd_t = measure(1, iters.max(3), || {
            let g = store.load_graph(&gkey).expect("graph record");
            let pp = store.load_p(&pkey).expect("P record");
            std::hint::black_box((g.n, pp.perplexity));
        })
        .median();
        let _ = std::fs::remove_dir_all(&dir);

        let mut rep = Report::new(
            &format!("durable store (checkpoint n={cn} = {mb:.1} MB; graph+P @ n={kn})"),
            &["median", "throughput"],
        );
        rep.row(
            "checkpoint encode",
            vec![format!("{:.2}ms", enc_t * 1e3), format!("{:.0} MB/s", mb / enc_t)],
        );
        rep.row(
            "checkpoint decode",
            vec![format!("{:.2}ms", dec_t * 1e3), format!("{:.0} MB/s", mb / dec_t)],
        );
        rep.row(
            "store write (graph+P)",
            vec![
                format!("{:.2}ms", wr_t * 1e3),
                format!("{:.0} MB/s", (graph_mb + p_mb) / wr_t),
            ],
        );
        rep.row(
            "store read (graph+P)",
            vec![
                format!("{:.2}ms", rd_t * 1e3),
                format!("{:.0} MB/s", (graph_mb + p_mb) / rd_t),
            ],
        );
        rep.print();
        rep.write_csv("micro_store.csv")?;
        json_sections.push((
            "store",
            Json::obj(vec![
                ("checkpoint_n", Json::Num(cn as f64)),
                ("checkpoint_mb", Json::Num(mb)),
                ("encode_ms", Json::Num(enc_t * 1e3)),
                ("decode_ms", Json::Num(dec_t * 1e3)),
                ("encode_mb_s", Json::Num(mb / enc_t)),
                ("decode_mb_s", Json::Num(mb / dec_t)),
                ("record_n", Json::Num(kn as f64)),
                ("record_mb", Json::Num(graph_mb + p_mb)),
                ("write_ms", Json::Num(wr_t * 1e3)),
                ("read_ms", Json::Num(rd_t * 1e3)),
                ("write_mb_s", Json::Num((graph_mb + p_mb) / wr_t)),
                ("read_mb_s", Json::Num((graph_mb + p_mb) / rd_t)),
            ]),
        ));
    }

    // --- Cluster routing (ARCHITECTURE.md §Cluster topology): the HRW
    // placement decision sits on every routed submit and every failover
    // re-admission, so it must stay negligible next to the RPC it
    // fronts. Full `owner_of` lookups (lock + scan + addr clone — the
    // real submit-path shape) at three fleet sizes, plus the raw score
    // primitive.
    {
        use gpgpu_sne::cluster::{hrw_score, Membership};

        let it = if quick { 3 } else { 6 };
        let lookups = if quick { 50_000u64 } else { 200_000 };
        let mut rep =
            Report::new("cluster routing (HRW placement decision)", &["ns/lookup"]);
        let mut size_rows: Vec<Json> = Vec::new();
        let mut worst_ns = 0.0f64;
        for &k in &[2usize, 8, 32] {
            let m = Membership::default();
            for w in 0..k {
                m.register(&format!("10.0.0.{w}:79{w:02}"));
            }
            let t = measure(1, it, || {
                let mut acc = 0u64;
                for key in 0..lookups {
                    let (owner, _) =
                        m.owner_of(key.wrapping_mul(0x9e37_79b9_7f4a_7c15)).unwrap();
                    acc ^= owner;
                }
                std::hint::black_box(acc);
            })
            .min();
            let ns = t * 1e9 / lookups as f64;
            worst_ns = worst_ns.max(ns);
            rep.row(&format!("owner_of, {k} workers"), vec![format!("{ns:.1}")]);
            size_rows.push(Json::obj(vec![
                ("workers", Json::Num(k as f64)),
                ("owner_of_ns", Json::Num(ns)),
            ]));
        }
        let score_ops = lookups * 4;
        let st = measure(1, it, || {
            let mut acc = 0u64;
            for i in 0..score_ops {
                acc ^= hrw_score(i, 0x1234_5678_9abc_def0);
            }
            std::hint::black_box(acc);
        })
        .min();
        let score_ns = st * 1e9 / score_ops as f64;
        rep.row("hrw_score primitive", vec![format!("{score_ns:.2}")]);
        rep.print();
        rep.write_csv("micro_cluster.csv")?;
        assert!(
            worst_ns < 1_000.0,
            "HRW placement costs {worst_ns:.0}ns/lookup — the routing decision must \
             stay <1µs next to the proxied RPC"
        );
        json_sections.push((
            "cluster",
            Json::obj(vec![
                ("hrw_score_ns", Json::Num(score_ns)),
                ("placements", Json::Arr(size_rows)),
                ("budget_ns", Json::Num(1_000.0)),
            ]),
        ));
    }

    // --- Ops tools (README §Operations): pallas-fsck's wall time is
    // the record verify scan (framing decode + FNV-1a over the whole
    // payload), and the CI perf gate adds one pallas-bench-trend
    // analysis per run — both pinned here so the tools stay cheap
    // enough to run casually against production-sized state dirs.
    {
        use gpgpu_sne::coordinator::store;
        use gpgpu_sne::tools::benchtrend;

        let mb = if quick { 4usize } else { 16 };
        let payload: Vec<u8> =
            (0..mb << 20).map(|i| (i as u64).wrapping_mul(0x9e37_79b9) as u8).collect();
        let dir = std::env::temp_dir().join(format!("gsne-bench-tools-{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        let rec_path = dir.join("g-bench.rec");
        store::write_record(&rec_path, store::KIND_GRAPH, &payload)?;
        let bytes = std::fs::read(&rec_path)?;
        let vt = measure(warmup, iters, || {
            let ok = store::verify_record_bytes(&bytes, store::KIND_GRAPH)
                .expect("bench record is healthy");
            std::hint::black_box(ok.len());
        })
        .min();
        let verify_mb_s = mb as f64 / vt;
        let _ = std::fs::remove_dir_all(&dir);

        let mk = |c: &str, speed: f64| {
            format!(
                r#"{{"commit":"{c}","bench":{{"simd":{{"tier":"avx2","kernels":[{{"name":"gd_fused","speedup":{speed}}},{{"name":"splat","speedup":{speed}}}]}},"cluster":{{"placements":[{{"workers":8,"owner_of_ns":250.0}}]}}}}}}"#
            )
        };
        let text = format!("{}\n{}\n", mk("aaaa", 2.5), mk("bbbb", 2.6));
        let entries = benchtrend::parse_history(&text).expect("bench history parses");
        let rules = benchtrend::default_rules();
        let reps = 1000u64;
        let ct = measure(warmup, iters, || {
            for _ in 0..reps {
                let a = benchtrend::analyze(&entries, None, &rules)
                    .expect("history analyzes")
                    .expect("two entries compare");
                std::hint::black_box(a.deltas.len());
            }
        })
        .min();
        let compare_us = ct * 1e6 / reps as f64;

        let mut rep = Report::new("ops tools (fsck verify scan, trend gate)", &["value"]);
        rep.row("record verify", vec![format!("{verify_mb_s:.0} MB/s")]);
        rep.row("trend analysis", vec![format!("{compare_us:.1} us")]);
        rep.print();
        rep.write_csv("micro_tools.csv")?;
        json_sections.push((
            "tools",
            Json::obj(vec![
                ("verify_mb_s", Json::Num(verify_mb_s)),
                ("record_mb", Json::Num(mb as f64)),
                ("trend_compare_us", Json::Num(compare_us)),
            ]),
        ));
    }

    // --- Machine-readable summary for cross-PR tracking, committed at
    // the workspace root (cargo runs benches with the *package* root as
    // cwd, hence the explicit path).
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_micro.json");
    let json = Json::obj(json_sections);
    std::fs::write(out, format!("{json}\n"))?;
    eprintln!("  [json] wrote {out}");
    Ok(())
}
