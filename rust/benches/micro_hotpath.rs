//! Micro-benchmarks of the hot paths (EXPERIMENTS.md §Perf): field
//! evaluation (the L1 kernel's CPU mirror, by grid and N), the device
//! step (by grid, measuring the full PJRT execute round-trip and its
//! host-boundary overhead), the repulsion baselines, attractive pass,
//! and the kNN structures.
//!
//!     cargo bench --bench micro_hotpath [-- --quick]

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::common::Repulsion;
use gpgpu_sne::embed::exact::ExactRepulsion;
use gpgpu_sne::embed::bh::BhRepulsion;
use gpgpu_sne::embed::fieldcpu::{compute_fields, grid_placement, FieldRepulsion};
use gpgpu_sne::hd::{kdforest, perplexity, vptree};
use gpgpu_sne::runtime::{self, Runtime, StepState};
use gpgpu_sne::util::bench::{measure, quick_mode, Report};
use gpgpu_sne::util::rng::Rng;

fn random_points(n: usize, seed: u64, spread: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let (warmup, iters) = if quick { (1, 3) } else { (2, 7) };

    // --- Field evaluation: grid × N scaling (the paper's O(N·ρ²) claim:
    // cost linear in N at fixed grid; quadratic in grid at fixed N).
    let mut rep = Report::new("fields eval (CPU mirror of the L1 kernel)", &["median", "per-point"]);
    for &(n, grid) in &[(1000usize, 64usize), (1000, 128), (1000, 256), (4000, 128), (16_000, 128)] {
        let y = random_points(n, 1, 10.0);
        let (origin, pixel) = grid_placement([-30.0, -30.0, 30.0, 30.0], grid);
        let st = measure(warmup, iters, || {
            let _ = compute_fields(&y, origin, pixel, grid);
        });
        rep.row(
            &format!("n={n} G={grid}"),
            vec![
                format!("{:.2}ms", st.median() * 1e3),
                format!("{:.2}µs", st.median() * 1e6 / n as f64),
            ],
        );
    }
    rep.print();
    rep.write_csv("micro_fields.csv")?;

    // --- Repulsion approaches at fixed n.
    let n = if quick { 2000 } else { 8000 };
    let y = random_points(n, 2, 20.0);
    let mut num = vec![0.0f32; 2 * n];
    let mut rep = Report::new(&format!("repulsion variants (n={n})"), &["median", "vs exact"]);
    let exact_t = measure(warmup, iters, || {
        ExactRepulsion.compute(&y, &mut num);
    })
    .median();
    rep.row("exact O(N²)", vec![format!("{:.1}ms", exact_t * 1e3), "1.0x".into()]);
    for theta in [0.1f32, 0.5] {
        let t = measure(warmup, iters, || {
            BhRepulsion { theta }.compute(&y, &mut num);
        })
        .median();
        rep.row(
            &format!("BH θ={theta}"),
            vec![format!("{:.1}ms", t * 1e3), format!("{:.1}x", exact_t / t)],
        );
    }
    for grid in [128usize, 256] {
        let mut fr = FieldRepulsion { min_grid: grid, max_grid: grid, ..Default::default() };
        let t = measure(warmup, iters, || {
            fr.compute(&y, &mut num);
        })
        .median();
        rep.row(
            &format!("field G={grid}"),
            vec![format!("{:.1}ms", t * 1e3), format!("{:.1}x", exact_t / t)],
        );
    }
    rep.print();
    rep.write_csv("micro_repulsion.csv")?;

    // --- Device step: per-grid execute cost + host-boundary overhead.
    if let Some(dir) = runtime::locate_artifacts() {
        let rt = Arc::new(Runtime::new(&dir)?);
        let mut rep = Report::new("device step (PJRT execute round-trip)", &["median", "per-point"]);
        let buckets: Vec<usize> = {
            let mut b: Vec<usize> = rt.manifest.steps().map(|a| a.n).collect();
            b.sort_unstable();
            b.dedup();
            b
        };
        for &npad in &buckets {
            for grid in rt.manifest.grids_for(npad) {
                let exe = rt.step_executable(npad, grid)?;
                let k = exe.spec.k;
                let mut mask = vec![0.0f32; npad];
                let n_real = npad * 3 / 4;
                mask[..n_real].fill(1.0);
                let idx = vec![0i32; npad * k];
                let mut pv = vec![0.0f32; npad * k];
                for i in 0..n_real {
                    pv[i * k] = 1.0 / n_real as f32;
                }
                let statics = rt.upload_static(&mask, &idx, &pv, k)?;
                let y0 = random_points(npad, 3, 5.0);
                let mut state = StepState::new(y0, &mask);
                let st = measure(warmup, iters, || {
                    let _ = rt.run_step(&exe, &mut state, &statics, 200.0, 0.5, 1.0).unwrap();
                });
                rep.row(
                    &format!("n={npad} G={grid}"),
                    vec![
                        format!("{:.2}ms", st.median() * 1e3),
                        format!("{:.2}µs", st.median() * 1e6 / n_real as f64),
                    ],
                );
            }
        }
        rep.print();
        rep.write_csv("micro_device_step.csv")?;
    } else {
        eprintln!("note: no artifacts — device-step section skipped");
    }

    // --- kNN structures.
    let kn = if quick { 2000 } else { 10_000 };
    let ds = gpgpu_sne::data::by_name("mnist", kn, 4)?;
    let mut rep = Report::new(&format!("kNN (n={kn}, d=784, k=90)"), &["median", "recall"]);
    let brute_t = measure(0, 1, || {
        let _ = compute_knn(&ds, KnnMethod::Brute, 90, 4);
    })
    .median();
    let exact = compute_knn(&ds, KnnMethod::Brute, 90, 4);
    rep.row("brute", vec![format!("{:.2}s", brute_t), "1.000".into()]);
    let vp_t = measure(0, 1, || {
        let _ = vptree::VpTree::build(&ds, 4).knn(90);
    })
    .median();
    let vp = vptree::VpTree::build(&ds, 4).knn(90);
    rep.row("vptree", vec![format!("{:.2}s", vp_t), format!("{:.3}", vp.recall_against(&exact))]);
    let kd_t = measure(0, 1, || {
        let _ = kdforest::KdForest::build(&ds, kdforest::ForestParams::default(), 4).knn(90);
    })
    .median();
    let kd = kdforest::KdForest::build(&ds, kdforest::ForestParams::default(), 4).knn(90);
    rep.row("kdforest", vec![format!("{:.2}s", kd_t), format!("{:.3}", kd.recall_against(&exact))]);
    rep.print();
    rep.write_csv("micro_knn.csv")?;

    // --- Perplexity + attractive pass.
    let p = perplexity::joint_p(&exact, 30.0);
    let y = random_points(kn, 6, 10.0);
    let mut attr = vec![0.0f32; 2 * kn];
    let at = measure(warmup, iters, || {
        let _ = gpgpu_sne::embed::attractive_forces(&p, &y, &mut attr);
    });
    let mut rep = Report::new("sparse passes", &["median"]);
    rep.row("attractive (n·k)", vec![format!("{:.2}ms", at.median() * 1e3)]);
    let pt = measure(0, 1, || {
        let _ = perplexity::joint_p(&exact, 30.0);
    });
    rep.row("perplexity+P build", vec![format!("{:.2}ms", pt.median() * 1e3)]);
    rep.print();
    rep.write_csv("micro_sparse.csv")?;
    Ok(())
}
