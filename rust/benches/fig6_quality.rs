//! Figure 6, rows 2–3 — embedding quality vs dataset size: final
//! KL-divergence (row 2) and Nearest-Neighbour-Preservation
//! precision/recall curves (row 3) on MNIST, WikiWord and Word2Vec,
//! engines as in row 1.
//!
//! Expected shape: field-based KL ≤ BH KL with the gap widening as N
//! grows (the paper's density argument); NNP curves of GPGPU-SNE dominate
//! the BH-based ones.
//!
//!     cargo bench --bench fig6_quality [-- --quick]

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::{self, OptParams};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::metrics::{kl, nnp};
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::bench::{quick_mode, Report};
use gpgpu_sne::util::image;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let ns: Vec<usize> = if quick { vec![500, 1500] } else { vec![1000, 2500] };
    let iters = if quick { 250 } else { 500 };
    let nnp_sample = 1000;

    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    let mut engines = vec!["exact", "bh-0.1", "bh-0.5", "tsne-cuda-0.5", "fieldcpu", "fieldfft"];
    if rt.is_some() {
        engines.push("gpgpu");
    }
    std::fs::create_dir_all("bench_out")?;

    for dataset in ["mnist", "wikiword", "word2vec"] {
        let mut kl_report = Report::new(
            &format!("Fig6 row 2 — final KL, {dataset} ({iters} iters)"),
            &engines.iter().map(|s| *s).collect::<Vec<_>>(),
        );
        let mut nnp_report = Report::new(
            &format!("Fig6 row 3 — NNP mean precision, {dataset}"),
            &engines.iter().map(|s| *s).collect::<Vec<_>>(),
        );
        for &n in &ns {
            let ds = gpgpu_sne::data::by_name(dataset, n, 5)?;
            let knn = compute_knn(&ds, KnnMethod::KdForest, 90.min(n / 2), 5);
            let p = perplexity::joint_p(&knn, 30.0);
            let params = OptParams { iters, ..Default::default() };
            let exact_cap = if quick { 800 } else { 2500 };

            let mut kl_cells = Vec::new();
            let mut nnp_cells = Vec::new();
            for name in &engines {
                let over_capacity = *name == "gpgpu"
                    && rt.as_ref().map(|r| n > r.manifest.max_bucket()).unwrap_or(true);
                if (*name == "exact" && n > exact_cap) || over_capacity {
                    kl_cells.push("—".into());
                    nnp_cells.push("—".into());
                    continue;
                }
                let runtime = if *name == "gpgpu" { rt.clone() } else { None };
                let mut e = embed::by_name(name, runtime)?;
                let y = e.run(&p, &params, None)?;
                let kl_v = kl::kl_divergence_exact(&p, &y);
                let curve = nnp::nnp_curve(&ds, &y, nnp_sample, 0);
                kl_cells.push(format!("{kl_v:.4}"));
                nnp_cells.push(format!("{:.3}", curve.mean_precision()));
                // Full PR curve to CSV (the actual row-3 figure series).
                let pr = format!("bench_out/fig6_nnp_{dataset}_n{n}_{name}.csv");
                image::write_csv(
                    &pr,
                    &["k", "precision", "recall"],
                    &[
                        (1..=30).map(|k| k as f64).collect(),
                        curve.precision.clone(),
                        curve.recall.clone(),
                    ],
                )?;
            }
            kl_report.row(&format!("N={n}"), kl_cells);
            nnp_report.row(&format!("N={n}"), nnp_cells);
        }
        kl_report.print();
        kl_report.write_csv(&format!("fig6_kl_{dataset}.csv"))?;
        nnp_report.print();
        nnp_report.write_csv(&format!("fig6_nnp_{dataset}.csv"))?;
    }
    println!("PR curves per (dataset, N, engine) written to bench_out/fig6_nnp_*.csv");
    Ok(())
}
