//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. ρ / grid resolution: accuracy (KL, force error) vs field cost —
//!    the paper's "ρ = 0.5 is a good compromise" claim (§4.2).
//! 2. Splat (bounded support, §5.1.2) vs gather (unbounded, §5.2):
//!    accuracy loss and cost of the rasterisation-style variant.
//! 3. Adaptive-grid hysteresis: executable switches with and without.
//! 4. Fused multi-step artifact (lax.scan) vs single-step: host-boundary
//!    amortisation on the device path.
//! 5. KD-forest parameters: trees/checks/refine vs recall and build+query
//!    time (the A-tSNE approximation dial).
//!
//!     cargo bench --bench ablation [-- --quick]

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::common::Repulsion;
use gpgpu_sne::embed::exact::ExactRepulsion;
use gpgpu_sne::embed::fieldcpu::{compute_fields, compute_fields_splat, grid_placement, FieldCpu, FieldRepulsion};
use gpgpu_sne::embed::fieldfft::FieldFft;
use gpgpu_sne::embed::gpgpu::GridPolicy;
use gpgpu_sne::embed::{Engine, OptParams};
use gpgpu_sne::field::conv::FftBackend;
use gpgpu_sne::field::{FieldBackend, Placement};
use gpgpu_sne::hd::{bruteforce, kdforest, perplexity};
use gpgpu_sne::metrics::kl;
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::bench::{measure, quick_mode, Report};
use gpgpu_sne::util::rng::Rng;

fn random_points(n: usize, seed: u64, spread: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..2 * n).map(|_| rng.gauss_f32(0.0, spread)).collect()
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let (warmup, iters) = if quick { (1, 3) } else { (2, 5) };

    // --- 1. Grid resolution (ρ) ablation.
    let n = if quick { 1000 } else { 4000 };
    let ds = gpgpu_sne::data::by_name("mnist", n, 2)?;
    let knn = compute_knn(&ds, KnnMethod::KdForest, 90.min(n / 2), 2);
    let p = perplexity::joint_p(&knn, 30.0);
    let opt = OptParams { iters: if quick { 150 } else { 400 }, ..Default::default() };
    let mut rep = Report::new(
        &format!("ρ ablation (fixed grid, n={n}) — accuracy vs cost"),
        &["KL(exact)", "optimize time", "force max-err"],
    );
    // Reference forces at a converged random layout for the error column.
    let y_probe = random_points(n, 7, 15.0);
    let mut exact_num = vec![0.0f32; 2 * n];
    ExactRepulsion.compute(&y_probe, &mut exact_num);
    let scale = exact_num.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    for grid in [16usize, 32, 64, 128, 256] {
        // Same fixed-grid sweep for both field backends: gather (fieldcpu)
        // and FFT convolution (fieldfft) — the accuracy cost of the O(N)
        // formulation rides along with the ρ ablation.
        for fft in [false, true] {
            let make_rep = || {
                if fft {
                    FieldRepulsion {
                        min_grid: grid,
                        max_grid: grid,
                        ..FieldRepulsion::with_backend(Box::new(FftBackend::new()))
                    }
                } else {
                    FieldRepulsion { min_grid: grid, max_grid: grid, ..Default::default() }
                }
            };
            let t = std::time::Instant::now();
            let y = if fft {
                FieldFft { rep: make_rep() }.run(&p, &opt, None)?
            } else {
                FieldCpu { rep: make_rep() }.run(&p, &opt, None)?
            };
            let secs = t.elapsed().as_secs_f64();
            let kl_v = kl::kl_divergence_exact(&p, &y);
            let mut num = vec![0.0f32; 2 * n];
            let mut fr = make_rep();
            fr.compute(&y_probe, &mut num);
            let err = num
                .iter()
                .zip(&exact_num)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max)
                / scale;
            rep.row(
                &format!("G={grid}{}", if fft { " fft" } else { "" }),
                vec![format!("{kl_v:.4}"), format!("{secs:.2}s"), format!("{:.1}%", err * 100.0)],
            );
        }
    }
    rep.print();
    rep.write_csv("ablation_grid.csv")?;

    // --- 2. Splat vs gather.
    let yn = if quick { 2000 } else { 8000 };
    let y = random_points(yn, 3, 20.0);
    let grid = 128;
    let (origin, pixel) = grid_placement([-60.0, -60.0, 60.0, 60.0], grid);
    let full = compute_fields(&y, origin, pixel, grid);
    let mut rep = Report::new(
        &format!("splat (bounded support) vs gather — n={yn}, G={grid}"),
        &["median", "S mass error"],
    );
    let gather_t = measure(warmup, iters, || {
        let _ = compute_fields(&y, origin, pixel, grid);
    })
    .median();
    rep.row("gather (unbounded)", vec![format!("{:.1}ms", gather_t * 1e3), "0.0%".into()]);
    let s_full: f64 = full[..grid * grid].iter().map(|&v| v as f64).sum();
    // The FFT backend: unbounded support like the gather, O(N + G² log G)
    // like the splat — the best of both axes of this ablation.
    {
        let mut backend = FftBackend::new();
        let placement = Placement { origin, pixel };
        let t = measure(warmup.max(1), iters, || {
            let _ = backend.compute(&y, placement, grid);
        })
        .median();
        let tex = backend.compute(&y, placement, grid);
        let s_fft: f64 = tex.tex[..grid * grid].iter().map(|&v| v as f64).sum();
        rep.row(
            "fft conv (unbounded)",
            vec![
                format!("{:.1}ms", t * 1e3),
                format!("{:.2}%", (1.0 - s_fft / s_full).abs() * 100.0),
            ],
        );
    }
    for support in [2.0f32, 5.0, 15.0] {
        let t = measure(warmup, iters, || {
            let _ = compute_fields_splat(&y, origin, pixel, grid, support);
        })
        .median();
        let cut = compute_fields_splat(&y, origin, pixel, grid, support);
        let s_cut: f64 = cut[..grid * grid].iter().map(|&v| v as f64).sum();
        rep.row(
            &format!("splat support={support}"),
            vec![
                format!("{:.1}ms", t * 1e3),
                format!("{:.1}%", (1.0 - s_cut / s_full) * 100.0),
            ],
        );
    }
    rep.print();
    rep.write_csv("ablation_splat.csv")?;

    // --- 3. Hysteresis ablation: grid switches over a noisy diameter walk.
    let mut rep = Report::new("adaptive-grid hysteresis (simulated diameter walk)", &["switches"]);
    for (label, hyst) in [("off (0%)", 0.0f32), ("paper (10%)", 0.10), ("wide (25%)", 0.25)] {
        let mut policy = GridPolicy::new(0.5, vec![32, 64, 128, 256]);
        policy.hysteresis = hyst;
        let mut rng = Rng::new(11);
        let mut d = 12.0f32;
        let mut last = 0usize;
        let mut switches = 0usize;
        for step in 0..1000 {
            // Growth + multiplicative noise, like a real optimisation.
            d = (d * (1.0 + 0.002)) * (1.0 + 0.08 * (rng.f32() - 0.5));
            let g = policy.choose(d);
            if last != 0 && g != last {
                switches += 1;
            }
            last = g;
            let _ = step;
        }
        rep.row(label, vec![format!("{switches}")]);
    }
    rep.print();
    rep.write_csv("ablation_hysteresis.csv")?;

    // --- 4. Fused multi-step artifact vs single-step (device path).
    if let Some(dir) = runtime::locate_artifacts() {
        let rt = Arc::new(Runtime::new(&dir)?);
        if let Some(fused_spec) = rt.manifest.find_fused(1024).cloned() {
            let single = rt.step_executable(1024, fused_spec.grid)?;
            let fused = rt.executable(&fused_spec.name)?;
            let k = fused_spec.k;
            let npad = 1024;
            let n_real = 700;
            let mut mask = vec![0.0f32; npad];
            mask[..n_real].fill(1.0);
            let idx = vec![0i32; npad * k];
            let mut pv = vec![0.0f32; npad * k];
            for i in 0..n_real {
                pv[i * k] = 1.0 / n_real as f32;
            }
            let statics = rt.upload_static(&mask, &idx, &pv, k)?;
            let mut rep = Report::new(
                &format!("fused scan ablation (n=1024, G={}, S={})", fused_spec.grid, fused_spec.steps),
                &["median / iter"],
            );
            let mut state = gpgpu_sne::runtime::StepState::new(random_points(npad, 5, 5.0), &mask);
            let t_single = measure(warmup, iters, || {
                let _ = rt.run_step(&single, &mut state, &statics, 200.0, 0.5, 1.0).unwrap();
            })
            .median();
            rep.row("single-step x1", vec![format!("{:.2}ms", t_single * 1e3)]);
            let mut state = gpgpu_sne::runtime::StepState::new(random_points(npad, 5, 5.0), &mask);
            let t_fused = measure(warmup, iters, || {
                let _ = rt.run_step(&fused, &mut state, &statics, 200.0, 0.5, 1.0).unwrap();
            })
            .median()
                / fused_spec.steps as f64;
            rep.row(
                &format!("fused x{}", fused_spec.steps),
                vec![format!("{:.2}ms", t_fused * 1e3)],
            );
            rep.print();
            rep.write_csv("ablation_fused.csv")?;
        } else {
            eprintln!("note: no fused artifact built (rerun aot without --no-scan)");
        }
    } else {
        eprintln!("note: no artifacts — fused-scan ablation skipped");
    }

    // --- 5. KD-forest parameter sweep.
    let kn = if quick { 2000 } else { 6000 };
    let ds = gpgpu_sne::data::by_name("wikiword", kn, 8)?;
    let exact = bruteforce::knn(&ds, 30);
    let mut rep = Report::new(&format!("kd-forest dial (n={kn}, d=300, k=30)"), &["time", "recall"]);
    for (trees, checks, refine) in
        [(1usize, 16usize, false), (4, 64, false), (4, 64, true), (8, 128, true)]
    {
        let params = kdforest::ForestParams { trees, checks, refine, ..Default::default() };
        let t = std::time::Instant::now();
        let g = kdforest::KdForest::build(&ds, params, 1).knn(30);
        let secs = t.elapsed().as_secs_f64();
        rep.row(
            &format!("trees={trees} checks={checks} refine={refine}"),
            vec![format!("{secs:.2}s"), format!("{:.3}", g.recall_against(&exact))],
        );
    }
    rep.print();
    rep.write_csv("ablation_kdforest.csv")?;
    Ok(())
}
