//! Figures 2 and 3 — the fields and the kernels.
//!
//! Converges an MNIST-like embedding, evaluates the scalar field S and the
//! vector field V over the embedding domain (Fig. 2 b-d) and writes them
//! as PGMs, plus the kernel functions S(d), V(d) of Fig. 3 as CSV.
//!
//!     cargo run --release --example fields_viz -- --n 5000 --grid 256

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::fieldcpu::{compute_fields, grid_placement};
use gpgpu_sne::embed::{self, OptParams};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::util::cli::Args;
use gpgpu_sne::util::image;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get("n", 5000usize, "points");
    let grid = args.get("grid", 256usize, "field texture resolution");
    let iters = args.get("iters", 400usize, "iterations");
    let out_dir = args.str("out-dir", "fig2_out", "output directory");
    let kernels_only = args.flag("kernels", "emit only the Fig. 3 kernel functions");
    args.finish_help("Figures 2-3: field textures and kernel functions");
    std::fs::create_dir_all(&out_dir)?;

    // Figure 3: the kernel functions S(d) = (1+d²)^-1 and V(d) = (1+d²)^-2 d.
    let rs: Vec<f64> = (0..601).map(|i| -3.0 + i as f64 * 0.01).collect();
    let s: Vec<f64> = rs.iter().map(|d| 1.0 / (1.0 + d * d)).collect();
    let v: Vec<f64> = rs.iter().map(|d| d / (1.0 + d * d).powi(2)).collect();
    image::write_csv(format!("{out_dir}/fig3_kernels.csv"), &["d", "S", "V"], &[rs, s, v])?;
    println!("wrote {out_dir}/fig3_kernels.csv");
    if kernels_only {
        return Ok(());
    }

    // Converge an embedding (Fig. 2a).
    let ds = gpgpu_sne::data::by_name("mnist", n, 7)?;
    let knn = compute_knn(&ds, KnnMethod::KdForest, 90, 7);
    let p = perplexity::joint_p(&knn, 30.0);
    let y = embed::by_name("fieldcpu", None)?.run(&p, &OptParams { iters, ..Default::default() }, None)?;
    image::write_embedding_pgm(format!("{out_dir}/fig2a_embedding.pgm"), &y, &ds.labels, 512)?;

    // Evaluate the fields over the converged embedding (Fig. 2 b-d).
    let mut bbox = [f32::INFINITY, f32::INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
    for i in 0..n {
        bbox[0] = bbox[0].min(y[2 * i]);
        bbox[1] = bbox[1].min(y[2 * i + 1]);
        bbox[2] = bbox[2].max(y[2 * i]);
        bbox[3] = bbox[3].max(y[2 * i + 1]);
    }
    let (origin, pixel) = grid_placement(bbox, grid);
    let t = std::time::Instant::now();
    let tex = compute_fields(&y, origin, pixel, grid);
    let plane = grid * grid;
    println!(
        "fields: {grid}x{grid} over bbox [{:.1},{:.1}]x[{:.1},{:.1}] in {:.1}ms",
        bbox[0],
        bbox[1],
        bbox[2],
        bbox[3],
        t.elapsed().as_secs_f64() * 1e3
    );
    image::write_pgm(format!("{out_dir}/fig2b_S.pgm"), &tex[..plane], grid, grid)?;
    image::write_pgm_signed(format!("{out_dir}/fig2c_Vx.pgm"), &tex[plane..2 * plane], grid, grid)?;
    image::write_pgm_signed(format!("{out_dir}/fig2d_Vy.pgm"), &tex[2 * plane..], grid, grid)?;
    println!("wrote {out_dir}/fig2[a-d]_*.pgm");

    // Sanity numbers mirroring the paper's description.
    let s_max = tex[..plane].iter().cloned().fold(0.0f32, f32::max);
    let zhat: f64 = (0..n)
        .map(|i| {
            let svv = gpgpu_sne::embed::fieldcpu::bilinear(&tex, grid, origin, pixel, y[2 * i], y[2 * i + 1]);
            (svv[0] - 1.0) as f64
        })
        .sum();
    println!("S peak density: {s_max:.2}; Zhat = {zhat:.1}");
    Ok(())
}
