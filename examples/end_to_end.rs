//! End-to-end validation driver (EXPERIMENTS.md §Headline).
//!
//! Runs the complete system on a real small workload: the MNIST(-like)
//! dataset through every pipeline stage, comparing the paper's field-based
//! minimiser (device `gpgpu` + CPU mirror) against exact t-SNE and
//! Barnes-Hut on *identical* P and initialisation, and reporting the
//! paper's headline quantities: per-engine optimisation time, exact final
//! KL divergence, and NNP precision/recall.
//!
//!     cargo run --release --example end_to_end -- --n 5000 --iters 1000

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::{self, OptParams};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::metrics::{kl, nnp};
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::bench::Report;
use gpgpu_sne::util::cli::Args;
use gpgpu_sne::util::timer::{fmt_secs, Timer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get("n", 5000usize, "points");
    let iters = args.get("iters", 1000usize, "iterations");
    let include_exact = n <= 3000 || args.flag("exact", "include the O(N²) engine at any n");
    args.finish_help("End-to-end driver: full pipeline, all engines, paper metrics");

    println!("== GPGPU-SNE end-to-end driver ==");
    let ds = gpgpu_sne::data::by_name("mnist", n, 42)?;
    println!("dataset {} (n={}, d={})", ds.name, ds.n, ds.d);

    let t = Timer::start();
    let knn = compute_knn(&ds, KnnMethod::KdForest, 90, 42);
    let knn_s = t.elapsed_s();
    let t = Timer::start();
    let p = perplexity::joint_p(&knn, 30.0);
    let perp_s = t.elapsed_s();
    println!("similarities: knn {} | perplexity {}\n", fmt_secs(knn_s), fmt_secs(perp_s));

    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    if rt.is_none() {
        eprintln!("note: no artifacts — gpgpu engine skipped (run `make artifacts`)");
    }

    let mut engines: Vec<&str> = Vec::new();
    if include_exact {
        engines.push("exact");
    }
    engines.extend(["bh-0.1", "bh-0.5", "tsne-cuda-0.5", "fieldcpu", "fieldfft"]);
    if rt.is_some() {
        engines.push("gpgpu");
    }

    let params = OptParams { iters, ..Default::default() };
    let mut report = Report::new(
        &format!("End-to-end on {} n={n}, {iters} iters", ds.name),
        &["time", "iters/s", "KL(exact)", "NNP mean-p", "NNP r@30"],
    );
    let mut baseline_bh_time = None;
    for name in engines {
        let mut engine = embed::by_name(name, rt.clone())?;
        let t = Timer::start();
        let y = engine.run(&p, &params, None)?;
        let secs = t.elapsed_s();
        if name == "bh-0.5" {
            baseline_bh_time = Some(secs);
        }
        let kl_v = kl::kl_divergence_exact(&p, &y);
        let curve = nnp::nnp_curve(&ds, &y, 1000, 0);
        println!(
            "{name:<14} {:>9}  KL={kl_v:.4}  NNP p̄={:.3}",
            fmt_secs(secs),
            curve.mean_precision()
        );
        report.row(
            name,
            vec![
                fmt_secs(secs),
                format!("{:.1}", iters as f64 / secs),
                format!("{kl_v:.4}"),
                format!("{:.3}", curve.mean_precision()),
                format!("{:.3}", curve.recall[29]),
            ],
        );
    }
    report.print();
    report.write_csv("end_to_end.csv")?;
    if let Some(bh) = baseline_bh_time {
        println!(
            "modelled t-SNE-CUDA time (BH θ=0.5 CPU / {}x GPU envelope): {}",
            gpgpu_sne::embed::tsnecuda::GPU_SPEEDUP_MODEL,
            fmt_secs(gpgpu_sne::embed::tsnecuda::TsneCudaSim::modelled_time(bh))
        );
    }
    println!("\nPaper headline check: field-based KL ≤ BH KL, NNP ≥ BH NNP; see EXPERIMENTS.md.");
    Ok(())
}
