//! Serving demo: start the embedding service on an ephemeral TCP port,
//! drive it as a client — submit several jobs (batched requests), stream
//! progressive snapshots, exercise early termination — and report
//! request latency / service throughput, the serving-paper readout.
//!
//!     cargo run --release --example serve -- --jobs 3 --n 1500

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use gpgpu_sne::coordinator::{protocol, EmbeddingService};
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::cli::Args;
use gpgpu_sne::util::json::{self, Json};
use gpgpu_sne::util::timer::{fmt_secs, Timer};

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> std::io::Result<Self> {
        let s = TcpStream::connect(addr)?;
        Ok(Self { reader: BufReader::new(s.try_clone()?), writer: s })
    }

    fn call(&mut self, req: &str) -> anyhow::Result<Json> {
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(json::parse(line.trim())?)
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let jobs = args.get("jobs", 3usize, "number of concurrent jobs");
    let n = args.get("n", 1500usize, "points per job");
    let iters = args.get("iters", 400usize, "iterations per job");
    args.finish_help("Serving demo: batched embedding requests over TCP");

    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    let engine = if rt.is_some() { "gpgpu" } else { "fieldcpu" };
    let svc = Arc::new(EmbeddingService::new(rt, 2));
    let (tx, rx) = std::sync::mpsc::channel();
    {
        let svc = svc.clone();
        std::thread::spawn(move || {
            let _ = protocol::serve(svc, "127.0.0.1:0", move |a| {
                let _ = tx.send(a);
            });
        });
    }
    let addr = rx.recv()?;
    println!("service listening on {addr} (engine: {engine})");

    // Submit a batch of jobs over separate client connections.
    let datasets = ["mnist", "wikiword", "imagenet-head0"];
    let wall = Timer::start();
    let mut submitted = Vec::new();
    for j in 0..jobs {
        let mut c = Client::connect(addr)?;
        let t = Timer::start();
        let resp = c.call(&format!(
            r#"{{"cmd":"submit","dataset":"{}","n":{n},"engine":"{engine}","iters":{iters},"snapshot_every":50,"seed":{j}}}"#,
            datasets[j % datasets.len()]
        ))?;
        let id = resp.num_field("job").expect("job id") as u64;
        println!("job {id} ({}) submitted in {}", datasets[j % datasets.len()], fmt_secs(t.elapsed_s()));
        submitted.push((id, c));
    }

    // Stream progress by polling status; stop the last job early to show
    // user-driven termination.
    let mut total_iters = 0usize;
    for (i, (id, c)) in submitted.iter_mut().enumerate() {
        if i + 1 == jobs && jobs > 1 {
            // Let it get going, then stop it (A-tSNE early termination).
            // The job may already have finished while earlier waits ran —
            // stop is then a harmless no-op.
            loop {
                let s = c.call(&format!(r#"{{"cmd":"status","job":{id}}}"#))?;
                let phase = s.str_field("phase").unwrap_or("").to_string();
                if phase.starts_with("optimizing") || s.get("terminal") == Some(&Json::Bool(true)) {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            c.call(&format!(r#"{{"cmd":"stop","job":{id}}}"#))?;
            println!("job {id}: early termination requested");
        }
        let t = Timer::start();
        let done = c.call(&format!(r#"{{"cmd":"wait","job":{id}}}"#))?;
        let iters_run = done.num_field("iters").unwrap_or(0.0) as usize;
        total_iters += iters_run;
        println!(
            "job {id}: {} iters, KL≈{:.4}, optimize {}, wait-latency {}{}",
            iters_run,
            done.num_field("kl").unwrap_or(f64::NAN),
            fmt_secs(done.num_field("optimize_s").unwrap_or(0.0)),
            fmt_secs(t.elapsed_s()),
            if done.get("stopped_early") == Some(&Json::Bool(true)) { "  [stopped early]" } else { "" },
        );
    }
    let wall_s = wall.elapsed_s();
    println!(
        "\nservice throughput: {jobs} jobs / {} = {:.2} jobs/min; {:.0} optimiser iters/s aggregate",
        fmt_secs(wall_s),
        jobs as f64 / wall_s * 60.0,
        total_iters as f64 / wall_s
    );
    Ok(())
}
