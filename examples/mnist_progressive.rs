//! Figure 1 — progressive evolution of the MNIST embedding.
//!
//! Runs the field-based optimiser through the coordinator service and
//! dumps an embedding snapshot (PGM + CSV) at the paper's milestones, plus
//! a per-snapshot timing/KL log — the "watch the embedding unfold in
//! seconds" experience the paper demonstrates in the browser.
//!
//!     cargo run --release --example mnist_progressive -- --n 10000

use std::sync::Arc;

use gpgpu_sne::coordinator::{EmbeddingService, JobSpec, KnnMethod};
use gpgpu_sne::embed::OptParams;
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::cli::Args;
use gpgpu_sne::util::image;
use gpgpu_sne::util::timer::fmt_secs;

const MILESTONES: &[usize] = &[0, 10, 50, 100, 250, 500, 999];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get("n", 10_000usize, "points");
    let iters = args.get("iters", 1000usize, "iterations");
    let out_dir = args.str("out-dir", "fig1_out", "output directory");
    args.finish_help("Figure 1: progressive MNIST embedding evolution");
    std::fs::create_dir_all(&out_dir)?;

    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    let engine = if rt.is_some() { "gpgpu" } else { "fieldcpu" };
    println!("engine: {engine} (n={n}, {iters} iterations)");

    let labels = gpgpu_sne::data::by_name("mnist", n, 42)?.labels;
    let svc = EmbeddingService::new(rt, 1);
    let spec = JobSpec {
        dataset: "mnist".into(),
        n,
        engine: engine.into(),
        perplexity: 30.0,
        knn: KnnMethod::KdForest,
        params: OptParams { iters, ..Default::default() },
        snapshot_every: 1,
        auto_stop: None,
        seed: 42,
        y0: None,
        resume_from: None,
    };
    let id = svc.submit(spec);
    let rx = svc.subscribe(id).unwrap();

    let mut next = 0usize;
    for snap in rx {
        if next < MILESTONES.len() && snap.iter >= MILESTONES[next].min(iters - 1) {
            let path = format!("{out_dir}/mnist_iter{:04}.pgm", snap.iter);
            image::write_embedding_pgm(&path, &snap.positions, &labels, 512)?;
            println!(
                "iter {:>4}  t={:>8}  KL≈{:.4}  -> {path}",
                snap.iter,
                fmt_secs(snap.elapsed_s),
                snap.kl_est
            );
            next += 1;
        }
        // The service keeps the broadcast alive for late subscribers, so
        // the stream does not close on its own — leave at the last iter.
        if snap.iter + 1 >= iters || next >= MILESTONES.len() {
            break;
        }
    }
    let res = svc.wait(id)?;
    println!(
        "\ncompleted {} iterations in {} (knn {} | perplexity {} | optimize {})",
        res.iters_run,
        fmt_secs(res.timings.total()),
        fmt_secs(res.timings.knn_s),
        fmt_secs(res.timings.perplexity_s),
        fmt_secs(res.timings.optimize_s)
    );
    println!("paper reference: tens of minutes in multithreaded C++ (BH-SNE), seconds on GPU.");
    Ok(())
}
