//! Quickstart: embed a small dataset with the paper's field-based engine
//! and print quality metrics — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart -- --n 2000 --engine gpgpu
//!
//! (Falls back from `gpgpu` to `fieldcpu` automatically when `make
//! artifacts` has not been run.)

use std::sync::Arc;

use gpgpu_sne::coordinator::pipeline::compute_knn;
use gpgpu_sne::coordinator::KnnMethod;
use gpgpu_sne::embed::{self, OptParams};
use gpgpu_sne::hd::perplexity;
use gpgpu_sne::metrics::{kl, nnp};
use gpgpu_sne::runtime::{self, Runtime};
use gpgpu_sne::util::cli::Args;
use gpgpu_sne::util::timer::{fmt_secs, Timer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get("n", 2000usize, "points");
    let iters = args.get("iters", 500usize, "iterations");
    let mut engine_name = args.str("engine", "gpgpu", "engine");
    args.finish_help("Quickstart: one embedding, start to finish");

    // 1. Data: an MNIST-like manifold mixture (or real MNIST if present).
    let ds = gpgpu_sne::data::by_name("mnist", n, 1)?;
    println!("dataset: {} (n={}, d={})", ds.name, ds.n, ds.d);

    // 2. Similarities: approximate kNN + perplexity calibration -> sparse P.
    let t = Timer::start();
    let knn = compute_knn(&ds, KnnMethod::KdForest, 90, 1);
    let p = perplexity::joint_p(&knn, 30.0);
    println!("similarities: k=90, perplexity=30 in {}", fmt_secs(t.elapsed_s()));

    // 3. Optimise with the paper's field-based minimiser.
    let rt = runtime::locate_artifacts().and_then(|d| Runtime::new(&d).ok()).map(Arc::new);
    if engine_name == "gpgpu" && rt.is_none() {
        eprintln!("note: no artifacts found, using the CPU field engine (run `make artifacts`)");
        engine_name = "fieldcpu".into();
    }
    let mut engine = embed::by_name(&engine_name, rt)?;
    let params = OptParams { iters, ..Default::default() };
    let t = Timer::start();
    let y = engine.run(&p, &params, None)?;
    let opt_s = t.elapsed_s();

    // 4. Quality: the paper's two metrics.
    let kl_final = kl::kl_divergence_exact(&p, &y);
    let curve = nnp::nnp_curve(&ds, &y, 1000, 0);
    println!(
        "\n{engine_name}: {iters} iterations in {} ({:.1} iters/s)",
        fmt_secs(opt_s),
        iters as f64 / opt_s
    );
    println!("KL divergence: {kl_final:.4}");
    println!(
        "NNP: mean precision {:.3}, recall@30 {:.3}",
        curve.mean_precision(),
        curve.recall[29]
    );
    gpgpu_sne::util::image::write_embedding_pgm("quickstart_embedding.pgm", &y, &ds.labels, 512)?;
    println!("wrote quickstart_embedding.pgm");
    Ok(())
}
